"""Control-flow graph, reaching definitions, and path distances (§III-B/C).

The paper computes reaching definitions for machine-register writes with a
forward GEN/KILL fixed point over the CFG, unions at joins, then does a
per-use intra-block walk plus a backward-liveness cross-block filter.

In the XLA adaptation the instruction stream is SSA *within* a computation,
so the interesting multi-definition "registers" are **loop-state slots**: a
while-loop's tuple element `i` is written both by the init tuple (preheader)
and by the body root (back edge).  We keep the paper's formalism: blocks are
computations (preheader = calling computation, body, exit), GEN/KILL sets are
over `(while_op, slot)` registers, and the fixed point produces the union of
reaching definitions that `depgraph.py` turns into REG_RAW and LOOP_CARRIED
edges.  Conditionals contribute joins (union over branch roots).

This module also owns the **path-distance model** used by Stage-3 latency
pruning and the blame distance factor: for each (producer, consumer) edge we
enumerate the structural CFG paths (straight-line, cross-computation,
loop-carried) and accumulate both instruction counts and issue cycles along
them, via per-computation prefix sums.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .hwmodel import HardwareModel
from .isa import Computation, Instruction, Module, OpClass


# --------------------------------------------------------------------------
# Reaching definitions over loop-state slots (GEN/KILL fixed point).
# --------------------------------------------------------------------------

Register = Tuple[str, int]          # (while-op qualified name, tuple slot)
Definition = Tuple[str, Register]   # (defining instruction qualified name, reg)


@dataclass
class Block:
    """One CFG block: a computation playing a structural role."""

    name: str                      # computation name
    role: str                      # preheader | body | exit | plain
    gen: Set[Definition] = field(default_factory=set)
    kill: Set[Register] = field(default_factory=set)
    succs: List[str] = field(default_factory=list)
    preds: List[str] = field(default_factory=list)
    reach_in: Set[Definition] = field(default_factory=set)
    reach_out: Set[Definition] = field(default_factory=set)


class LoopSlotDataflow:
    """Forward reaching-definitions fixed point for while-loop state slots."""

    def __init__(self, module: Module):
        self.module = module
        self.blocks: Dict[str, Block] = {}
        self._build()
        self._fixed_point()

    def _build(self) -> None:
        mod = self.module
        for comp_name, comp in mod.computations.items():
            self.blocks[comp_name] = Block(name=comp_name, role=comp.kind)
        for comp_name, comp in mod.computations.items():
            for instr in comp.instructions:
                if instr.opcode != "while":
                    continue
                body = self._body_of(instr)
                if body is None:
                    continue
                reg_base = instr.qualified_name
                # Edges: caller -> body, body -> body (back edge), body -> caller.
                self._link(comp_name, body.name)
                self._link(body.name, body.name)
                self._link(body.name, comp_name)
                # GEN at preheader: init tuple elements.
                init = comp.get(instr.operands[0]) if instr.operands else None
                n_slots = self._slot_count(instr)
                for slot in range(n_slots):
                    reg: Register = (reg_base, slot)
                    src = self._tuple_element(comp, init, slot) if init else None
                    if src is not None:
                        self.blocks[comp_name].gen.add((src.qualified_name, reg))
                        self.blocks[comp_name].kill.add(reg)
                # GEN at body: root tuple elements (the back-edge definitions).
                root = body.root
                for slot in range(n_slots):
                    reg = (reg_base, slot)
                    src = self._tuple_element(body, root, slot)
                    if src is not None:
                        self.blocks[body.name].gen.add((src.qualified_name, reg))
                        self.blocks[body.name].kill.add(reg)

    def _link(self, a: str, b: str) -> None:
        if b not in self.blocks[a].succs:
            self.blocks[a].succs.append(b)
        if a not in self.blocks[b].preds:
            self.blocks[b].preds.append(a)

    def _body_of(self, while_instr: Instruction) -> Optional[Computation]:
        for cname in while_instr.called_computations:
            comp = self.module.computations.get(cname)
            if comp is not None and comp.kind == "loop_body":
                return comp
        return None

    def _slot_count(self, while_instr: Instruction) -> int:
        if while_instr.shape.is_tuple:
            return len(while_instr.shape.elements)
        return 1

    def _tuple_element(self, comp: Computation, instr: Optional[Instruction],
                       slot: int) -> Optional[Instruction]:
        if instr is None:
            return None
        if instr.opcode == "tuple" and slot < len(instr.operands):
            return comp.get(instr.operands[slot])
        return instr  # non-tuple root: slot 0 is the value itself

    def _fixed_point(self) -> None:
        changed = True
        while changed:
            changed = False
            for block in self.blocks.values():
                new_in: Set[Definition] = set()
                for p in block.preds:
                    new_in |= self.blocks[p].reach_out  # union at joins
                new_out = block.gen | {
                    d for d in new_in if d[1] not in block.kill}
                if new_in != block.reach_in or new_out != block.reach_out:
                    block.reach_in, block.reach_out = new_in, new_out
                    changed = True

    def reaching_defs(self, body_comp: str, while_qualified: str,
                      slot: int) -> List[Tuple[str, bool]]:
        """Definitions of loop slot reaching the body entry.

        Returns (defining instruction qualified name, is_loop_carried).
        """
        block = self.blocks.get(body_comp)
        if block is None:
            return []
        reg: Register = (while_qualified, slot)
        out: List[Tuple[str, bool]] = []
        for def_name, def_reg in block.reach_in:
            if def_reg == reg:
                carried = def_name.split("::")[0] == body_comp
                out.append((def_name, carried))
        return out

    def slot_live_in_body(self, body_comp: str, slot: int) -> bool:
        """Backward-liveness cross-block filter (§III-B): a loop-carried
        definition is only a candidate if the slot is actually read in the
        body (via get-tuple-element on the state parameter)."""
        comp = self.module.computations.get(body_comp)
        if comp is None:
            return False
        params = {p.name for p in comp.parameters}
        for instr in comp.instructions:
            if instr.opcode == "get-tuple-element" and instr.operands and \
                    instr.operands[0] in params:
                if int(instr.attributes.get("index", -1)) == slot:
                    return True
        # Non-tuple state: any direct use of the parameter.
        if slot == 0:
            for instr in comp.instructions:
                if any(op in params for op in instr.operands):
                    return True
        return False


# --------------------------------------------------------------------------
# Path distances (Stage-3 latency pruning + blame distance factor).
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class PathInfo:
    """One structural CFG path from producer to consumer."""

    instr_count: float    # instructions issued strictly between the two
    issue_cycles: float   # accumulated issue cycles along the path
    kind: str             # straight | loop_carried | cross_comp


class DistanceModel:
    """Per-computation prefix sums of issue cycles for O(1) path segments."""

    def __init__(self, module: Module, hw: HardwareModel):
        self.module = module
        self.hw = hw
        self._prefix: Dict[str, List[float]] = {}
        for cname, comp in module.computations.items():
            acc = [0.0]
            for instr in comp.instructions:
                acc.append(acc[-1] + hw.issue_cycles(instr))
            self._prefix[cname] = acc

    def segment_cycles(self, comp: str, lo: int, hi: int) -> float:
        """Issue cycles of instructions with index in (lo, hi) exclusive."""
        if hi <= lo + 1:
            return 0.0
        pre = self._prefix[comp]
        return pre[hi] - pre[lo + 1]

    def body_cycles(self, comp: str) -> float:
        return self._prefix[comp][-1]

    def straight(self, producer: Instruction, consumer: Instruction) -> PathInfo:
        assert producer.computation == consumer.computation
        return PathInfo(
            instr_count=max(0, consumer.index - producer.index - 1),
            issue_cycles=self.segment_cycles(
                producer.computation, producer.index, consumer.index),
            kind="straight")

    def loop_carried(self, producer: Instruction,
                     consumer: Instruction) -> PathInfo:
        """producer (late in body, iter k) -> consumer (early in body, k+1)."""
        comp = producer.computation
        n = len(self.module.computations[comp].instructions)
        tail = self.segment_cycles(comp, producer.index, n)
        head = self.segment_cycles(comp, -1, consumer.index)
        count = (n - producer.index - 1) + consumer.index
        return PathInfo(instr_count=max(0, count),
                        issue_cycles=tail + head, kind="loop_carried")

    def cross_comp(self, producer: Instruction, call_site: Instruction,
                   consumer: Instruction) -> PathInfo:
        """producer in caller -> call-site -> consumer inside callee."""
        up = self.straight(producer, call_site)
        inner = self.segment_cycles(consumer.computation, -1, consumer.index)
        return PathInfo(
            instr_count=up.instr_count + consumer.index,
            issue_cycles=up.issue_cycles + inner, kind="cross_comp")
