"""Structured stall reports and diagnostic-context formats (paper §IV).

Three context levels for downstream optimizers (human, LLM, or the
deterministic rule-engine used by the Table-V benchmark analogue):

  C       — code only;
  C+S     — code plus raw per-instruction stall counts (what vendor
            profilers give you);
  C+L(S)  — code plus LEO's full root-cause analysis: ranked dependency
            chains with blame attribution, scope (cross-layer) paths,
            quantified cycles, and actionable recommendations.

The recommendation rules map root-cause *patterns* to concrete
transformations with machine-readable action ids, so the paper's claim —
"structured dependency chains guide optimization better than raw metrics" —
is testable here: the rule engine can act on C+L(S) but can only guess from
C+S (it sees symptoms without causes).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .passes import LeoAnalysis
from .isa import EdgeKind, Instruction, OpClass, StallClass


@dataclass
class Recommendation:
    action: str          # machine-readable id (rule engine key)
    target: str          # qualified instruction name
    scope: str           # op_name scope of the target
    reason: str          # human-readable explanation
    est_cycles: float    # blame cycles addressed by this action


_COLLECTIVE_OPS = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                   "collective-permute"}


def recommendations(analysis: LeoAnalysis, limit: int = 8
                    ) -> List[Recommendation]:
    recs: List[Recommendation] = []
    seen_actions = set()

    def add(action: str, target: str, scope: str, reason: str,
            cycles: float) -> None:
        key = (action, scope.rsplit("/", 1)[0] if scope else target)
        if key in seen_actions:
            return
        seen_actions.add(key)
        recs.append(Recommendation(action=action, target=target, scope=scope,
                                   reason=reason, est_cycles=cycles))

    for qualified, cycles in analysis.blame.top_root_causes(24):
        instr = analysis.module.find(qualified)
        if instr is None:
            continue
        base = instr.opcode.replace("-start", "")
        scope = instr.op_name
        if base in _COLLECTIVE_OPS:
            if instr.comm_bytes > 0:
                add("overlap_or_reshard_collective", qualified, scope,
                    f"{base} moves {instr.comm_bytes/2**20:.1f} MiB over ICI "
                    f"per chip and blocks consumers; reshard to eliminate it "
                    f"or overlap it with compute.", cycles)
        elif instr.opcode in ("gather", "dynamic-slice"):
            add("coalesce_or_tile_gather", qualified, scope,
                "Indirect/strided load dominates stalls; restructure layout "
                "or tile the accessed table into VMEM.", cycles)
        elif instr.op_class is OpClass.PARAMETER:
            add("cache_weights_vmem", qualified, scope,
                "Streaming this operand from HBM bounds the consumer; raise "
                "arithmetic intensity (fuse consumers / cache in VMEM / "
                "re-tile).", cycles)
        elif instr.op_class is OpClass.MATMUL:
            add("increase_matmul_intensity", qualified, scope,
                "Dependent matmul chain limits ILP; enlarge tiles, batch "
                "small matmuls, or break the serial chain.", cycles)
        elif instr.op_class in (OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE,
                                OpClass.DATA_MOVEMENT):
            add("prefetch_or_double_buffer", qualified, scope,
                "Exposed copy/load latency; issue the transfer earlier or "
                "double-buffer.", cycles)
        elif instr.op_class is OpClass.FUSION and instr.bytes_read > 0 and \
                instr.flops / max(instr.bytes_read + instr.bytes_written,
                                  1.0) < 2.0:
            add("refuse_or_remat", qualified, scope,
                "Low-arithmetic-intensity fused loop is HBM-bound; refuse "
                "with producers/consumers or change remat policy.", cycles)

    # Loop-carried serialization pattern.
    carried = [e for e in analysis.graph.alive_edges
               if e.kind is EdgeKind.LOOP_CARRIED]
    if carried:
        carried_blame = sum(analysis.blame.by_producer.get(e.producer, 0.0)
                            for e in carried)
        if carried_blame > 0.05 * max(analysis.profile.total_stall_cycles, 1):
            e0 = max(carried, key=lambda e:
                     analysis.blame.by_producer.get(e.producer, 0.0))
            instr = analysis.module.find(e0.producer)
            add("pipeline_loop_iterations", e0.producer,
                instr.op_name if instr else "",
                "Loop-carried dependency serializes iterations; software-"
                "pipeline or widen the recurrence.", carried_blame)

    diagnosed = list(analysis.blame.self_blame) + \
        list(getattr(analysis.blame, "occupancy_blame", []))
    for s in sorted(diagnosed, key=lambda s: -s.cycles)[:4]:
        instr = analysis.module.find(s.qualified)
        scope = instr.op_name if instr else ""
        if s.subcategory == "memory latency":
            add("tile_into_vmem", s.qualified, scope,
                "Self-blamed memory latency with no producer to indict; the "
                "access itself is the bottleneck — tile into VMEM.",
                s.cycles)
        elif s.subcategory == "compute saturation":
            add("already_compute_bound", s.qualified, scope,
                "Compute-saturated: optimization headroom is limited "
                "(reduce FLOPs or change precision).", s.cycles)
        elif s.subcategory == "indirect addressing":
            add("coalesce_or_tile_gather", s.qualified, scope,
                "Indirect addressing self-stall.", s.cycles)

    recs.sort(key=lambda r: -r.est_cycles)
    return recs[:limit]


# --------------------------------------------------------------------------
# Structured (JSON-able) report — the C+L(S) payload.
# --------------------------------------------------------------------------

def structured_report(analysis: LeoAnalysis, max_chains: int = 5) -> dict:
    chains = []
    for chain in analysis.chains[:max_chains]:
        chains.append({
            "stall_cycles": chain.total_stall_cycles,
            "links": [{
                "instruction": l.qualified,
                "opcode": l.opcode,
                "edge": l.edge_kind.value if l.edge_kind else None,
                "blame_cycles": l.blame_cycles,
                "scope": l.op_name,
                "source": l.source,
            } for l in chain.links],
        })
    backend = analysis.backend
    stalls = []
    for rec in analysis.profile.top_stalled(10):
        instr = analysis.module.find(rec.qualified)
        entry = {
            "instruction": rec.qualified,
            "opcode": instr.opcode if instr else "?",
            "scope": instr.op_name if instr else "",
            "latency_samples": rec.latency_samples,
            "total_samples": rec.total_samples,
            "breakdown": {k.value: v for k, v in rec.stall_breakdown.items()},
        }
        if backend is not None:
            # the same counters in the vendor profiler's own vocabulary
            # (CUPTI / rocprofiler / Level Zero / xplane), for agents that
            # cross-check against native tool output
            entry["native_breakdown"] = {
                backend.native_stall_name(k): v
                for k, v in rec.stall_breakdown.items()}
        stalls.append(entry)
    report_head = {
        "backend": analysis.hw.name,
        "module": analysis.module.name,
    }
    if backend is not None:
        report_head["vendor"] = backend.vendor
        report_head["stall_taxonomy"] = backend.taxonomy_table()
    return {
        **report_head,
        "estimated_step_seconds": analysis.estimated_step_seconds,
        "total_stall_cycles": analysis.profile.total_stall_cycles,
        "single_dependency_coverage": {
            "before": analysis.coverage_before.coverage,
            "after": analysis.coverage_after.coverage,
        },
        "pruning": {
            "initial_edges": analysis.prune_stats.initial_edges,
            "pruned": analysis.prune_stats.pruned_by_stage,
            "surviving": analysis.prune_stats.surviving_edges,
        },
        "top_stalls": stalls,
        "root_cause_chains": chains,
        "root_causes": [
            {"instruction": q, "blame_cycles": c,
             "scope": (analysis.module.find(q).op_name
                       if analysis.module.find(q) else "")}
            for q, c in analysis.blame.top_root_causes(10)],
        "self_blame": [
            {"instruction": s.qualified, "cycles": s.cycles,
             "subcategory": s.subcategory}
            for s in analysis.blame.self_blame[:10]],
        "recommendations": [
            {"action": r.action, "target": r.target, "scope": r.scope,
             "reason": r.reason, "est_cycles": r.est_cycles}
            for r in recommendations(analysis)],
    }


# --------------------------------------------------------------------------
# Diagnostic-context levels for the §IV study.
# --------------------------------------------------------------------------

def context_c(code: str) -> str:
    return f"### Kernel source\n```\n{code}\n```\n"


def context_cs(code: str, analysis: LeoAnalysis) -> str:
    """Code + raw per-instruction stall counts (vendor-profiler level)."""
    lines = [context_c(code), "### Raw stall counts (PC sampling)"]
    for rec in analysis.profile.top_stalled(15):
        instr = analysis.module.find(rec.qualified)
        op = instr.opcode if instr else "?"
        brk = ", ".join(f"{k.value}={v:,.0f}"
                        for k, v in rec.stall_breakdown.items())
        lines.append(f"- `{rec.qualified}` [{op}]: "
                     f"{rec.latency_samples:,.0f} stall cycles ({brk})")
    return "\n".join(lines) + "\n"


def context_cls(code: str, analysis: LeoAnalysis) -> str:
    """Code + LEO's full root-cause analysis (the paper's C+L(S))."""
    rep = structured_report(analysis)
    lines = [context_c(code), "### LEO root-cause analysis"]
    lines.append(f"Estimated step time: "
                 f"{rep['estimated_step_seconds']*1e3:.3f} ms on "
                 f"{rep['backend']}")
    lines.append("#### Ranked dependency chains (symptom -> root cause)")
    for i, chain in enumerate(analysis.chains[:5]):
        lines.append(f"Chain {i+1} "
                     f"({chain.total_stall_cycles:,.0f} stall cycles):")
        lines.append(chain.describe())
    lines.append("#### Recommendations")
    for r in rep["recommendations"]:
        lines.append(f"- [{r['action']}] {r['reason']} "
                     f"(~{r['est_cycles']:,.0f} cycles at `{r['target']}`"
                     f"{', scope ' + r['scope'] if r['scope'] else ''})")
    return "\n".join(lines) + "\n"


def diagnostic_context(level: str, code: str,
                       analysis: Optional[LeoAnalysis] = None) -> str:
    if level == "C":
        return context_c(code)
    if analysis is None:
        raise ValueError("levels C+S and C+L(S) require an analysis")
    if level == "C+S":
        return context_cs(code, analysis)
    if level == "C+L(S)":
        return context_cls(code, analysis)
    raise ValueError(f"unknown context level {level!r}")


def save_json(analysis: LeoAnalysis, path: str) -> None:
    with open(path, "w") as f:
        json.dump(structured_report(analysis), f, indent=2)
