"""Typed diagnosis schema + diagnostic-context formats (paper §IV).

The serving-grade result object is :class:`Diagnosis` — a versioned,
JSON-round-trippable snapshot of one LEO analysis that survives without
the in-memory ``LeoAnalysis`` (module, graphs, profile) it came from, so
it can be cached on disk, shipped over a queue, and handed to humans or
LLM agents:

    diag = Diagnosis.from_analysis(analysis)
    diag.to_json()                 # lossless: Diagnosis.from_json round-trips
    diag.to_markdown()             # human-readable report
    diag.to_llm_context("C+L(S)", code=src)   # §IV agent context

Three context levels for downstream optimizers (human, LLM, or the
deterministic rule-engine used by the Table-V benchmark analogue):

  C       — code only;
  C+S     — code plus raw per-instruction stall counts (what vendor
            profilers give you);
  C+L(S)  — code plus LEO's full root-cause analysis: ranked dependency
            chains with blame attribution, scope (cross-layer) paths,
            quantified cycles, and actionable recommendations.

The recommendation rules map root-cause *patterns* to concrete
transformations with machine-readable action ids, so the paper's claim —
"structured dependency chains guide optimization better than raw metrics" —
is testable here: the rule engine can act on C+L(S) but can only guess from
C+S (it sees symptoms without causes).

The pre-service free functions (``structured_report``, ``recommendations``,
``diagnostic_context``, ``save_json``) remain as deprecation shims that
delegate to :class:`Diagnosis`, so their output is byte-identical to the
methods they wrap (asserted in ``tests/test_service.py``).
"""
from __future__ import annotations

import json
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .passes import LeoAnalysis
from .isa import EdgeKind, Instruction, OpClass, StallClass

#: Version stamped into every serialized Diagnosis / AnalyzeRequest; readers
#: reject (treat as cache miss) payloads from a newer schema generation.
#: v2 added the ``sync_resources`` section (§III-E finite sync-resource
#: pressure); v3 added the ``issue_pressure`` section (multi-stream
#: issue-queue / scheduler-contention pressure); v4 added the ``advice``
#: section (ranked what-if-replayed optimization advice from
#: ``repro.advisor``); v5 added the ``rewrites`` section (applied,
#: equivalence-checked HLO rewrites from ``repro.rewrite`` with
#: predicted-vs-realized speedups); v6 added the ``occupancy`` section
#: (wave-residency / failed-latency-hiding pressure from the multi-wave
#: sampler).  Older payloads are still readable — ``from_dict`` migrates
#: them with explicit "not recorded" defaults, so a warm disk cache
#: survives each bump.
SCHEMA_VERSION = 6

#: Oldest payload generation ``Diagnosis.from_dict`` can migrate forward.
MIN_SCHEMA_VERSION = 1

#: The ``sync_resources`` default filled into migrated pre-v2 payloads.
SYNC_RESOURCES_NOT_RECORDED = {
    "recorded": False,
    "note": "not recorded (schema version 1 payload)",
}

#: The ``issue_pressure`` default filled into migrated pre-v3 payloads.
ISSUE_PRESSURE_NOT_RECORDED = {
    "recorded": False,
    "note": "not recorded (pre-v3 schema payload)",
}

#: The ``advice`` default: migrated pre-v4 payloads AND v4 diagnoses whose
#: request did not opt into the advisor (``advise=False`` skips the what-if
#: replays) — one constant, so both paths serialize identically and the
#: wire inverse-migration test can compare them byte-for-byte.
ADVICE_NOT_RECORDED = {
    "recorded": False,
    "note": "not recorded (advisor not run, or pre-v4 schema payload)",
}

#: The ``rewrites`` default: migrated pre-v5 payloads AND v5 diagnoses
#: whose request did not opt into the rewrite loop (``rewrite=False``
#: skips the transform + re-analysis) — one constant, so both paths
#: serialize identically (same contract as ``ADVICE_NOT_RECORDED``).
REWRITES_NOT_RECORDED = {
    "recorded": False,
    "note": "not recorded (rewrite loop not run, or pre-v5 schema payload)",
}

#: The ``occupancy`` default: migrated pre-v6 payloads AND v6 diagnoses
#: analyzed at W=1 (``occupancy=False`` requests keep the single-wave
#: sampler, which carries no residency pressure) — one constant, so both
#: paths serialize identically (same contract as ``ADVICE_NOT_RECORDED``).
OCCUPANCY_NOT_RECORDED = {
    "recorded": False,
    "note": "not recorded (single-wave analysis, or pre-v6 schema payload)",
}


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.core.report.{old} is deprecated; use {new} instead "
        f"(shim slated for removal two releases after the LeoService API "
        f"landed — see docs/api.md)",
        DeprecationWarning, stacklevel=3)


@dataclass
class Recommendation:
    action: str          # machine-readable id (rule engine key)
    target: str          # qualified instruction name
    scope: str           # op_name scope of the target
    reason: str          # human-readable explanation
    est_cycles: float    # blame cycles addressed by this action

    def to_dict(self) -> Dict[str, Any]:
        return {"action": self.action, "target": self.target,
                "scope": self.scope, "reason": self.reason,
                "est_cycles": self.est_cycles}

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "Recommendation":
        return cls(action=data["action"], target=data["target"],
                   scope=data["scope"], reason=data["reason"],
                   est_cycles=data["est_cycles"])


_COLLECTIVE_OPS = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                   "collective-permute"}


def _build_recommendations(analysis: LeoAnalysis, limit: int = 8
                           ) -> List[Recommendation]:
    recs: List[Recommendation] = []
    seen_actions = set()

    def add(action: str, target: str, scope: str, reason: str,
            cycles: float) -> None:
        key = (action, scope.rsplit("/", 1)[0] if scope else target)
        if key in seen_actions:
            return
        seen_actions.add(key)
        recs.append(Recommendation(action=action, target=target, scope=scope,
                                   reason=reason, est_cycles=cycles))

    for qualified, cycles in analysis.blame.top_root_causes(24):
        instr = analysis.module.find(qualified)
        if instr is None:
            continue
        base = instr.opcode.replace("-start", "")
        scope = instr.op_name
        if base in _COLLECTIVE_OPS:
            if instr.comm_bytes > 0:
                add("overlap_or_reshard_collective", qualified, scope,
                    f"{base} moves {instr.comm_bytes/2**20:.1f} MiB over ICI "
                    f"per chip and blocks consumers; reshard to eliminate it "
                    f"or overlap it with compute.", cycles)
        elif instr.opcode in ("gather", "dynamic-slice"):
            add("coalesce_or_tile_gather", qualified, scope,
                "Indirect/strided load dominates stalls; restructure layout "
                "or tile the accessed table into VMEM.", cycles)
        elif instr.op_class is OpClass.PARAMETER:
            add("cache_weights_vmem", qualified, scope,
                "Streaming this operand from HBM bounds the consumer; raise "
                "arithmetic intensity (fuse consumers / cache in VMEM / "
                "re-tile).", cycles)
        elif instr.op_class is OpClass.MATMUL:
            add("increase_matmul_intensity", qualified, scope,
                "Dependent matmul chain limits ILP; enlarge tiles, batch "
                "small matmuls, or break the serial chain.", cycles)
        elif instr.op_class in (OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE,
                                OpClass.DATA_MOVEMENT):
            add("prefetch_or_double_buffer", qualified, scope,
                "Exposed copy/load latency; issue the transfer earlier or "
                "double-buffer.", cycles)
        elif instr.op_class is OpClass.FUSION and instr.bytes_read > 0 and \
                instr.flops / max(instr.bytes_read + instr.bytes_written,
                                  1.0) < 2.0:
            add("refuse_or_remat", qualified, scope,
                "Low-arithmetic-intensity fused loop is HBM-bound; refuse "
                "with producers/consumers or change remat policy.", cycles)

    # Loop-carried serialization pattern.
    carried = [e for e in analysis.graph.alive_edges
               if e.kind is EdgeKind.LOOP_CARRIED]
    if carried:
        carried_blame = sum(analysis.blame.by_producer.get(e.producer, 0.0)
                            for e in carried)
        if carried_blame > 0.05 * max(analysis.profile.total_stall_cycles, 1):
            e0 = max(carried, key=lambda e:
                     analysis.blame.by_producer.get(e.producer, 0.0))
            instr = analysis.module.find(e0.producer)
            add("pipeline_loop_iterations", e0.producer,
                instr.op_name if instr else "",
                "Loop-carried dependency serializes iterations; software-"
                "pipeline or widen the recurrence.", carried_blame)

    diagnosed = list(analysis.blame.self_blame) + \
        list(getattr(analysis.blame, "occupancy_blame", []))
    for s in sorted(diagnosed, key=lambda s: -s.cycles)[:4]:
        instr = analysis.module.find(s.qualified)
        scope = instr.op_name if instr else ""
        if s.subcategory == "memory latency":
            add("tile_into_vmem", s.qualified, scope,
                "Self-blamed memory latency with no producer to indict; the "
                "access itself is the bottleneck — tile into VMEM.",
                s.cycles)
        elif s.subcategory == "compute saturation":
            add("already_compute_bound", s.qualified, scope,
                "Compute-saturated: optimization headroom is limited "
                "(reduce FLOPs or change precision).", s.cycles)
        elif s.subcategory == "indirect addressing":
            add("coalesce_or_tile_gather", s.qualified, scope,
                "Indirect addressing self-stall.", s.cycles)

    recs.sort(key=lambda r: -r.est_cycles)
    return recs[:limit]


# --------------------------------------------------------------------------
# Diagnosis — the versioned, serializable analysis result.
# --------------------------------------------------------------------------

@dataclass
class Diagnosis:
    """Self-contained, JSON-pure snapshot of one analysis.

    Every field is built from plain JSON types (str/int/float/list/dict/
    None) except ``recommendations`` (a list of :class:`Recommendation`),
    so ``Diagnosis.from_json(d.to_json()) == d`` holds exactly (property-
    tested with hypothesis in ``tests/test_service.py``).
    """

    backend: str = ""
    module_name: str = ""
    estimated_step_seconds: float = 0.0
    total_stall_cycles: float = 0.0
    coverage_before: float = 0.0
    coverage_after: float = 0.0
    pruning: Dict[str, Any] = field(default_factory=dict)
    top_stalls: List[Dict[str, Any]] = field(default_factory=list)
    chains: List[Dict[str, Any]] = field(default_factory=list)
    root_causes: List[Dict[str, Any]] = field(default_factory=list)
    self_blame: List[Dict[str, Any]] = field(default_factory=list)
    recommendations: List[Recommendation] = field(default_factory=list)
    vendor: Optional[str] = None
    stall_taxonomy: Optional[Dict[str, str]] = None
    # §III-E finite sync-resource pressure (schema v2): per-pool capacity /
    # peak-in-flight / oversubscription events naming concrete resource
    # instances, or {"recorded": False, ...} when the analysis carried none.
    sync_resources: Dict[str, Any] = field(
        default_factory=lambda: dict(SYNC_RESOURCES_NOT_RECORDED))
    # Multi-stream issue-queue pressure (schema v3): the backend's
    # IssueModel (queues/width/policy), per-queue occupancy, and
    # scheduler-contention (not_selected / pipe_busy) cycles + events, or
    # {"recorded": False, ...} when the analysis carried none (measured
    # profiles, pre-v3 payloads).
    issue_pressure: Dict[str, Any] = field(
        default_factory=lambda: dict(ISSUE_PRESSURE_NOT_RECORDED))
    # Ranked optimization advice (schema v4): what-if-replayed candidate
    # mutations from `repro.advisor` with modeled speedups and vendor-
    # native phrasing, or {"recorded": False, ...} when the advisor was
    # not run (advise=False requests, measured profiles, pre-v4 payloads).
    advice: Dict[str, Any] = field(
        default_factory=lambda: dict(ADVICE_NOT_RECORDED))
    # Applied HLO rewrites (schema v5): equivalence-checked transforms
    # from `repro.rewrite` with predicted-vs-realized speedups per the
    # re-analyzed rewritten text, or {"recorded": False, ...} when the
    # rewrite loop was not run (rewrite=False requests, pre-v5 payloads).
    rewrites: Dict[str, Any] = field(
        default_factory=lambda: dict(REWRITES_NOT_RECORDED))
    # Wave-residency pressure (schema v6): the OccupancyModel the sampler
    # ran under (waves/limiter/window), hidden-vs-exposed stall accounting
    # per issue queue, and failed-latency-hiding (OCCUPANCY_LIMITED) blame
    # events, or {"recorded": False, ...} for W=1 analyses and pre-v6
    # payloads.
    occupancy: Dict[str, Any] = field(
        default_factory=lambda: dict(OCCUPANCY_NOT_RECORDED))
    schema_version: int = SCHEMA_VERSION

    # -- construction ----------------------------------------------------------

    @classmethod
    def from_analysis(cls, analysis: LeoAnalysis, max_chains: int = 5,
                      max_stalls: int = 15) -> "Diagnosis":
        # max_stalls=15 preserves the legacy C+S context fidelity (its
        # shim rendered top_stalled(15)); the report dict consequently
        # carries 15 stall records where the pre-schema one carried 10 —
        # an additive change under the versioned schema.
        chains = []
        for chain in analysis.chains[:max_chains]:
            chains.append({
                "stall_cycles": chain.total_stall_cycles,
                "links": [{
                    "instruction": l.qualified,
                    "opcode": l.opcode,
                    "edge": l.edge_kind.value if l.edge_kind else None,
                    "blame_cycles": l.blame_cycles,
                    "scope": l.op_name,
                    "source": l.source,
                } for l in chain.links],
                "text": chain.describe(),
            })
        backend = analysis.backend
        stalls = []
        for rec in analysis.profile.top_stalled(max_stalls):
            instr = analysis.module.find(rec.qualified)
            entry = {
                "instruction": rec.qualified,
                "opcode": instr.opcode if instr else "?",
                "scope": instr.op_name if instr else "",
                "latency_samples": rec.latency_samples,
                "total_samples": rec.total_samples,
                "breakdown": {k.value: v
                              for k, v in rec.stall_breakdown.items()},
            }
            if backend is not None:
                # the same counters in the vendor profiler's own vocabulary
                # (CUPTI / rocprofiler / Level Zero / xplane), for agents
                # that cross-check against native tool output
                entry["native_breakdown"] = {
                    backend.native_stall_name(k): v
                    for k, v in rec.stall_breakdown.items()}
            stalls.append(entry)
        sync_resources: Dict[str, Any] = dict(SYNC_RESOURCES_NOT_RECORDED)
        pressure = getattr(analysis, "sync_pressure", None)
        if pressure is not None:
            sync_resources = {"recorded": True}
            sync_resources.update(pressure.to_dict())
            sync_resources["blame"] = [
                {"consumer": b.consumer, "resource": b.resource,
                 "pool": b.pool, "holder": b.holder, "cycles": b.cycles}
                for b in getattr(analysis.blame, "sync_resource", [])[:10]]
        issue_pressure: Dict[str, Any] = dict(ISSUE_PRESSURE_NOT_RECORDED)
        ipressure = getattr(analysis, "issue_pressure", None)
        if ipressure is not None:
            issue_pressure = {"recorded": True}
            issue_pressure.update(ipressure.to_dict())
            issue_pressure["blame"] = [
                {"consumer": b.consumer, "holder": b.holder,
                 "queue": b.queue, "pipe": b.pipe,
                 "stall_class": b.stall_class, "cycles": b.cycles}
                for b in getattr(analysis.blame,
                                 "scheduler_contention", [])[:10]]
        occupancy: Dict[str, Any] = dict(OCCUPANCY_NOT_RECORDED)
        opressure = getattr(analysis, "occupancy_pressure", None)
        if opressure is not None:
            occupancy = {"recorded": True}
            occupancy.update(opressure.to_dict())
            occupancy["blame"] = [
                {"consumer": b.consumer, "blocker": b.blocker,
                 "queue": b.queue, "stall_class": b.stall_class,
                 "hidden_cycles": b.hidden_cycles,
                 "exposed_cycles": b.exposed_cycles}
                for b in getattr(analysis.blame,
                                 "occupancy_limited", [])[:10]]
        return cls(
            backend=analysis.hw.name,
            module_name=analysis.module.name,
            estimated_step_seconds=analysis.estimated_step_seconds,
            total_stall_cycles=analysis.profile.total_stall_cycles,
            coverage_before=analysis.coverage_before.coverage,
            coverage_after=analysis.coverage_after.coverage,
            pruning={
                "initial_edges": analysis.prune_stats.initial_edges,
                "pruned": dict(analysis.prune_stats.pruned_by_stage),
                "surviving": analysis.prune_stats.surviving_edges,
            },
            top_stalls=stalls,
            chains=chains,
            root_causes=[
                {"instruction": q, "blame_cycles": c,
                 "scope": (analysis.module.find(q).op_name
                           if analysis.module.find(q) else "")}
                for q, c in analysis.blame.top_root_causes(10)],
            self_blame=[
                {"instruction": s.qualified, "cycles": s.cycles,
                 "subcategory": s.subcategory}
                for s in analysis.blame.self_blame[:10]],
            recommendations=_build_recommendations(analysis),
            vendor=backend.vendor if backend is not None else None,
            stall_taxonomy=(backend.taxonomy_table()
                            if backend is not None else None),
            sync_resources=sync_resources,
            issue_pressure=issue_pressure,
            occupancy=occupancy,
        )

    # -- serialization ---------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """The structured C+L(S) payload (superset of the legacy
        ``structured_report`` dict; ``vendor``/``stall_taxonomy`` are
        omitted when the analysis carried no Backend descriptor, matching
        the legacy shape)."""
        out: Dict[str, Any] = {
            "schema_version": self.schema_version,
            "backend": self.backend,
            "module": self.module_name,
        }
        if self.vendor is not None:
            out["vendor"] = self.vendor
        if self.stall_taxonomy is not None:
            out["stall_taxonomy"] = dict(self.stall_taxonomy)
        out.update({
            "estimated_step_seconds": self.estimated_step_seconds,
            "total_stall_cycles": self.total_stall_cycles,
            "single_dependency_coverage": {
                "before": self.coverage_before,
                "after": self.coverage_after,
            },
            "pruning": self.pruning,
            "top_stalls": self.top_stalls,
            "root_cause_chains": self.chains,
            "root_causes": self.root_causes,
            "self_blame": self.self_blame,
            "sync_resources": self.sync_resources,
            "issue_pressure": self.issue_pressure,
            "advice": self.advice,
            "rewrites": self.rewrites,
            "occupancy": self.occupancy,
            "recommendations": [r.to_dict() for r in self.recommendations],
        })
        return out

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "Diagnosis":
        version = data.get("schema_version", 0)
        if not (MIN_SCHEMA_VERSION <= version <= SCHEMA_VERSION):
            raise ValueError(
                f"Diagnosis schema_version {version} outside supported "
                f"range [{MIN_SCHEMA_VERSION}, {SCHEMA_VERSION}]")
        # Graceful migration: v1 payloads (pre-sync_resources), v2
        # payloads (pre-issue_pressure), v3 payloads (pre-advice), v4
        # payloads (pre-rewrites) and v5 payloads (pre-occupancy) read
        # fine — a warm disk cache survives each schema bump with an
        # explicit "not recorded" default instead of a reject.
        sync_resources = data.get("sync_resources")
        if sync_resources is None:
            sync_resources = dict(SYNC_RESOURCES_NOT_RECORDED)
        issue_pressure = data.get("issue_pressure")
        if issue_pressure is None:
            issue_pressure = dict(ISSUE_PRESSURE_NOT_RECORDED)
        advice = data.get("advice")
        if advice is None:
            advice = dict(ADVICE_NOT_RECORDED)
        rewrites = data.get("rewrites")
        if rewrites is None:
            rewrites = dict(REWRITES_NOT_RECORDED)
        occupancy = data.get("occupancy")
        if occupancy is None:
            occupancy = dict(OCCUPANCY_NOT_RECORDED)
        cov = data.get("single_dependency_coverage", {})
        return cls(
            backend=data["backend"],
            module_name=data["module"],
            estimated_step_seconds=data["estimated_step_seconds"],
            total_stall_cycles=data["total_stall_cycles"],
            coverage_before=cov.get("before", 0.0),
            coverage_after=cov.get("after", 0.0),
            pruning=data.get("pruning", {}),
            top_stalls=data.get("top_stalls", []),
            chains=data.get("root_cause_chains", []),
            root_causes=data.get("root_causes", []),
            self_blame=data.get("self_blame", []),
            recommendations=[Recommendation.from_dict(r)
                             for r in data.get("recommendations", [])],
            vendor=data.get("vendor"),
            stall_taxonomy=data.get("stall_taxonomy"),
            sync_resources=sync_resources,
            issue_pressure=issue_pressure,
            advice=advice,
            rewrites=rewrites,
            occupancy=occupancy,
            schema_version=SCHEMA_VERSION,
        )

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=False)

    def copy(self) -> "Diagnosis":
        """Deep copy via the (lossless) JSON round-trip — used by the
        service caches so caller-side mutation cannot poison a cached or
        disk-persisted entry.  (The dict round-trip would alias the
        nested lists/dicts; serializing breaks every reference.)"""
        return Diagnosis.from_json(self.to_json())

    @classmethod
    def from_json(cls, payload: str) -> "Diagnosis":
        return cls.from_dict(json.loads(payload))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2))

    # -- presentation ----------------------------------------------------------

    def _sync_resource_lines(self) -> List[str]:
        """Human-readable §III-E resource-pressure lines ("barrier slots
        6/6 in flight at peak") shared by the markdown and LLM views."""
        sr = self.sync_resources or {}
        if not sr.get("recorded"):
            return []
        lines: List[str] = []
        for pool in sr.get("pools", []):
            if not pool.get("acquisitions"):
                continue
            line = (f"{pool.get('label', pool.get('pool', '?'))}: peak "
                    f"{pool.get('peak_in_flight', 0)}/"
                    f"{pool.get('capacity', 0)} in flight")
            if pool.get("evictions"):
                line += (f", {pool['evictions']} oversubscription event(s)"
                         f", {pool.get('contention_cycles', 0.0):,.0f} "
                         f"serialized stall cycles")
            lines.append(line)
        for b in sr.get("blame", [])[:3]:
            lines.append(
                f"`{b['consumer']}` serialized on {b['pool']} instance "
                f"`{b['resource']}` held by `{b['holder']}` "
                f"({b['cycles']:,.0f} cycles)")
        return lines

    def _issue_pressure_lines(self) -> List[str]:
        """Human-readable scheduler-contention lines ("4 issue queues,
        12,345 not_selected cycles") shared by markdown and LLM views."""
        ip = self.issue_pressure or {}
        if not ip.get("recorded") or not ip.get("contended"):
            return []
        lines = [
            f"{ip.get('queues', 1)} issue queue(s) x width "
            f"{ip.get('width', 1)} ({ip.get('policy', '?')}): "
            f"{ip.get('not_selected_cycles', 0.0):,.0f} not_selected + "
            f"{ip.get('pipe_busy_cycles', 0.0):,.0f} pipe_busy stall cycles"
        ]
        for q in ip.get("per_queue", []):
            contention = (q.get("not_selected_cycles", 0.0)
                          + q.get("pipe_busy_cycles", 0.0))
            if contention > 0:
                lines.append(
                    f"queue {q['queue']}: {q.get('issued', 0.0):,.0f} issues"
                    f", {contention:,.0f} contention cycles")
        for b in ip.get("blame", [])[:3]:
            lines.append(
                f"`{b['consumer']}` lost queue {b['queue']} arbitration to "
                f"`{b['holder']}` ({b['stall_class']}, "
                f"{b['cycles']:,.0f} cycles)")
        return lines

    def _advice_lines(self, top_k: int = 5) -> List[str]:
        """Human-readable ranked-advice lines ("1.32x batch bar.sync …")
        shared by the markdown and LLM views; empty when not recorded."""
        adv = self.advice or {}
        if not adv.get("recorded"):
            return []
        lines: List[str] = []
        for item in adv.get("items", [])[:top_k]:
            mut = item.get("mutation", {})
            mut_bits = ", ".join(f"{k}={v}" for k, v in mut.items()
                                 if k != "kind" and v is not None)
            lines.append(
                f"**{item.get('modeled_speedup', 0.0):.2f}x modeled** "
                f"[{item.get('rule', '?')}] {item.get('description', '')} "
                f"(what-if: {mut.get('kind', '?')}"
                + (f" {mut_bits}" if mut_bits else "")
                + f"; confidence {item.get('confidence', 0.0):.2f})")
        return lines

    def _occupancy_lines(self) -> List[str]:
        """Human-readable wave-residency lines ("8 resident waves, 54% of
        hideable latency covered") shared by markdown and LLM views."""
        occ = self.occupancy or {}
        if not occ.get("recorded"):
            return []
        lines = [
            f"{occ.get('waves', 1)} resident wave(s) per queue "
            f"({occ.get('limiter', 'none')}-limited, "
            f"{occ.get('window_cycles', 0.0):,.0f}-cycle hiding window): "
            f"{occ.get('hidden_cycles', 0.0):,.0f} stall cycles hidden, "
            f"{occ.get('exposed_cycles', 0.0):,.0f} exposed "
            f"({occ.get('hidden_fraction', 0.0):.0%} hidden)"
        ]
        if occ.get("limited"):
            lines.append(
                f"latency hiding ran out of waves: "
                f"{occ.get('occupancy_limited_cycles', 0.0):,.0f} "
                f"occupancy-limited stall cycles leaked through")
        for q in occ.get("per_queue", []):
            if q.get("hidden_cycles", 0.0) or q.get("exposed_cycles", 0.0):
                lines.append(
                    f"queue {q['queue']}: {q.get('hidden_cycles', 0.0):,.0f}"
                    f" hidden / {q.get('exposed_cycles', 0.0):,.0f} exposed"
                    f" cycles")
        for b in occ.get("blame", [])[:3]:
            lines.append(
                f"`{b['consumer']}` outran queue {b['queue']}'s waves "
                f"waiting on `{b['blocker']}` ({b['stall_class']}: "
                f"{b['hidden_cycles']:,.0f} hidden, "
                f"{b['exposed_cycles']:,.0f} exposed cycles)")
        return lines

    def _rewrite_lines(self, top_k: int = 5) -> List[str]:
        """Human-readable applied-rewrite lines ("1.32x realized (100% of
        predicted) CoalesceSyncTags …") shared by the markdown and LLM
        views; empty when not recorded."""
        rw = self.rewrites or {}
        if not rw.get("recorded"):
            return []
        lines: List[str] = []
        for item in rw.get("items", [])[:top_k]:
            mut = item.get("mutation", {})
            lines.append(
                f"**{item.get('realized_speedup', 0.0):.2f}x realized** "
                f"({item.get('realized_fraction', 0.0):.0%} of the "
                f"{item.get('predicted_speedup', 0.0):.2f}x predicted) "
                f"[{item.get('rule', '?')}, {item.get('source', '?')}] "
                f"{mut.get('kind', '?')} — certificate: "
                f"{item.get('certificate', {}).get('declared', '?')}")
        for s in rw.get("skipped", [])[:3]:
            refusal = s.get("refusal", {})
            lines.append(
                f"skipped [{s.get('rule', '?')}]: "
                f"{refusal.get('code', '?')} — {refusal.get('reason', '')}")
        return lines

    def to_markdown(self) -> str:
        """Human-readable report (the profiler-UI rendering)."""
        lines = [
            f"# LEO diagnosis — `{self.module_name}` on `{self.backend}`",
            "",
            f"- estimated step time: "
            f"**{self.estimated_step_seconds*1e3:.3f} ms**",
            f"- total stall cycles: {self.total_stall_cycles:,.0f}",
            f"- single-dependency coverage: {self.coverage_before:.0%} -> "
            f"{self.coverage_after:.0%} after sync/prune",
            f"- edges: {self.pruning.get('initial_edges', 0)} -> "
            f"{self.pruning.get('surviving', 0)} after pruning",
        ]
        if self.vendor:
            lines.append(f"- vendor: {self.vendor}")
        if self.root_causes:
            lines += ["", "## Top root causes", ""]
            for rc in self.root_causes[:5]:
                lines.append(f"1. `{rc['instruction']}` — "
                             f"{rc['blame_cycles']:,.0f} blamed cycles"
                             + (f" (scope `{rc['scope']}`)"
                                if rc.get("scope") else ""))
        if self.chains:
            lines += ["", "## Ranked dependency chains", ""]
            for i, chain in enumerate(self.chains):
                lines.append(f"### Chain {i+1} "
                             f"({chain['stall_cycles']:,.0f} stall cycles)")
                lines += ["```", chain.get("text", ""), "```"]
        sync_lines = self._sync_resource_lines()
        if sync_lines:
            lines += ["", "## Sync-resource pressure (§III-E)", ""]
            lines += [f"- {l}" for l in sync_lines]
        issue_lines = self._issue_pressure_lines()
        if issue_lines:
            lines += ["", "## Issue-queue contention", ""]
            lines += [f"- {l}" for l in issue_lines]
        occ_lines = self._occupancy_lines()
        if occ_lines:
            lines += ["", "## Wave occupancy (latency hiding)", ""]
            lines += [f"- {l}" for l in occ_lines]
        advice_lines = self._advice_lines()
        if advice_lines:
            lines += ["", "## Optimization advice (what-if replayed)", ""]
            lines += [f"- {l}" for l in advice_lines]
        rewrite_lines = self._rewrite_lines()
        if rewrite_lines:
            lines += ["", "## Applied rewrites (predicted vs realized)", ""]
            lines += [f"- {l}" for l in rewrite_lines]
        if self.recommendations:
            lines += ["", "## Recommendations", ""]
            for r in self.recommendations:
                lines.append(f"- **{r.action}** at `{r.target}`: {r.reason} "
                             f"(~{r.est_cycles:,.0f} cycles)")
        return "\n".join(lines) + "\n"

    def to_llm_context(self, level: str, code: str = "") -> str:
        """§IV diagnostic-context payloads (C / C+S / C+L(S) / C+L(S,A)
        — the last appends the ranked what-if-replayed advice)."""
        if level == "C":
            return _context_c(code)
        if level == "C+S":
            lines = [_context_c(code), "### Raw stall counts (PC sampling)"]
            for s in self.top_stalls:
                brk = ", ".join(f"{k}={v:,.0f}"
                                for k, v in s["breakdown"].items())
                lines.append(f"- `{s['instruction']}` [{s['opcode']}]: "
                             f"{s['latency_samples']:,.0f} stall cycles "
                             f"({brk})")
            return "\n".join(lines) + "\n"
        if level in ("C+L(S)", "C+L(S,A)"):
            lines = [_context_c(code), "### LEO root-cause analysis"]
            lines.append(f"Estimated step time: "
                         f"{self.estimated_step_seconds*1e3:.3f} ms on "
                         f"{self.backend}")
            lines.append("#### Ranked dependency chains "
                         "(symptom -> root cause)")
            for i, chain in enumerate(self.chains):
                lines.append(f"Chain {i+1} "
                             f"({chain['stall_cycles']:,.0f} stall cycles):")
                lines.append(chain.get("text", ""))
            sync_lines = self._sync_resource_lines()
            if sync_lines:
                lines.append("#### Vendor sync-resource pressure")
                lines += [f"- {l}" for l in sync_lines]
            issue_lines = self._issue_pressure_lines()
            if issue_lines:
                lines.append("#### Issue-queue (scheduler) contention")
                lines += [f"- {l}" for l in issue_lines]
            occ_lines = self._occupancy_lines()
            if occ_lines:
                lines.append("#### Wave occupancy (latency hiding)")
                lines += [f"- {l}" for l in occ_lines]
            lines.append("#### Recommendations")
            for r in self.recommendations:
                lines.append(f"- [{r.action}] {r.reason} "
                             f"(~{r.est_cycles:,.0f} cycles at `{r.target}`"
                             f"{', scope ' + r.scope if r.scope else ''})")
            if level == "C+L(S,A)":
                advice_lines = self._advice_lines()
                lines.append("#### Ranked optimization advice "
                             "(what-if replayed)")
                if advice_lines:
                    lines += [f"- {l}" for l in advice_lines]
                else:
                    lines.append("- (advice not recorded: the request did "
                                 "not run the advisor)")
                rewrite_lines = self._rewrite_lines()
                if rewrite_lines:
                    lines.append("#### Applied rewrites "
                                 "(predicted vs realized)")
                    lines += [f"- {l}" for l in rewrite_lines]
            return "\n".join(lines) + "\n"
        raise ValueError(f"unknown context level {level!r}")


def _context_c(code: str) -> str:
    return f"### Kernel source\n```\n{code}\n```\n"


# --------------------------------------------------------------------------
# Deprecation shims — byte-identical delegates to Diagnosis.
# --------------------------------------------------------------------------

def recommendations(analysis: LeoAnalysis, limit: int = 8
                    ) -> List[Recommendation]:
    """Deprecated: use ``Diagnosis.from_analysis(analysis).recommendations``."""
    _deprecated("recommendations", "Diagnosis.from_analysis(...).recommendations")
    return _build_recommendations(analysis, limit)


def structured_report(analysis: LeoAnalysis, max_chains: int = 5) -> dict:
    """Deprecated: use ``Diagnosis.from_analysis(analysis).to_dict()``."""
    _deprecated("structured_report", "Diagnosis.from_analysis(...).to_dict()")
    return Diagnosis.from_analysis(analysis, max_chains=max_chains).to_dict()


def context_c(code: str) -> str:
    """Deprecated: use ``Diagnosis.to_llm_context('C', code=...)``."""
    return _context_c(code)


def context_cs(code: str, analysis: LeoAnalysis) -> str:
    """Deprecated: use ``Diagnosis.to_llm_context('C+S', code=...)``."""
    return Diagnosis.from_analysis(analysis).to_llm_context("C+S", code=code)


def context_cls(code: str, analysis: LeoAnalysis) -> str:
    """Deprecated: use ``Diagnosis.to_llm_context('C+L(S)', code=...)``."""
    return Diagnosis.from_analysis(analysis).to_llm_context("C+L(S)",
                                                            code=code)


def diagnostic_context(level: str, code: str,
                       analysis: Optional[LeoAnalysis] = None) -> str:
    """Deprecated: use ``Diagnosis.to_llm_context(level, code=...)``."""
    _deprecated("diagnostic_context", "Diagnosis.to_llm_context(level, code)")
    if level == "C":
        return _context_c(code)
    if analysis is None:
        raise ValueError("levels C+S and C+L(S) require an analysis")
    if level in ("C+S", "C+L(S)"):
        return Diagnosis.from_analysis(analysis).to_llm_context(level,
                                                                code=code)
    raise ValueError(f"unknown context level {level!r}")


def save_json(analysis: LeoAnalysis, path: str) -> None:
    """Deprecated: use ``Diagnosis.from_analysis(analysis).save(path)``."""
    _deprecated("save_json", "Diagnosis.from_analysis(...).save(path)")
    with open(path, "w") as f:
        json.dump(Diagnosis.from_analysis(analysis).to_dict(), f, indent=2)
