"""`LeoSession`: the cached facade over the pass pipeline.

A session owns three content-addressed caches so production callers (the
benchmark harness, a profiling service fanning one trace out to N vendor
models) never re-do work:

  * **parse cache** — HLO text (sha256 + hints) -> parsed ``Module``;
  * **graph cache** — (module, backend) -> pristine dependency graph;
    pipeline passes mutate graphs (sync edges, prune marks), so the cache
    stores an untouched copy and hands out cheap structural clones that
    share ``Instruction``/``PathInfo`` objects but own their ``Edge``s;
  * **analysis cache** — (module, backend, options) -> ``LeoAnalysis``.

All three tiers are bounded LRU maps (``*_cache_size=None`` keeps the
legacy unbounded behavior) and the whole session is **thread-safe**: every
cache fill is single-flighted, so N threads racing on the same HLO text
produce exactly one parse / one graph build / one pipeline run while the
others wait for the winner's result.  ``compare_backends`` fanned out over
a thread pool (see ``LeoService``) therefore keeps the parse-once
invariant — asserted against ``session.stats`` in the tier-1 tests.

When a :class:`~repro.core.caching.DiskCache` is attached, parse misses
consult the content-addressed on-disk tier before parsing, so a *second
process* pointed at a warm cache directory performs zero HLO parses.
"""
from __future__ import annotations

import hashlib
import threading
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

from .backends import Backend, BackendLike, list_backends, resolve_backend
from .caching import DiskCache, LRUCache
from .depgraph import DependencyGraph, Edge, build_dependency_graph
from .hlo_parser import parse_hlo
from .isa import Module
from .passes import DEFAULT_PIPELINE, LeoAnalysis, Pipeline
from .sampler import StallProfile


@dataclass
class SessionStats:
    parse_calls: int = 0
    parse_misses: int = 0
    parse_disk_hits: int = 0
    graph_requests: int = 0
    graph_builds: int = 0
    analyze_calls: int = 0
    analyze_misses: int = 0

    @property
    def parse_hits(self) -> int:
        return self.parse_calls - self.parse_misses - self.parse_disk_hits

    @property
    def graph_hits(self) -> int:
        return self.graph_requests - self.graph_builds

    @property
    def analyze_hits(self) -> int:
        return self.analyze_calls - self.analyze_misses

    def as_dict(self) -> Dict[str, int]:
        return {
            "parse_calls": self.parse_calls, "parse_hits": self.parse_hits,
            "parse_disk_hits": self.parse_disk_hits,
            "graph_requests": self.graph_requests,
            "graph_hits": self.graph_hits,
            "analyze_calls": self.analyze_calls,
            "analyze_hits": self.analyze_hits,
        }


def _clone_graph(graph: DependencyGraph) -> DependencyGraph:
    """Structural clone: shares the Module and per-edge PathInfo objects
    (immutable), owns the Edge records and index lists (mutated by the
    sync/prune passes)."""
    clone = DependencyGraph(module=graph.module)
    for e in graph.edges:
        clone.add(Edge(producer=e.producer, consumer=e.consumer, kind=e.kind,
                       paths=list(e.paths), pruned_by=e.pruned_by,
                       resource=e.resource))
    return clone


class _SingleFlight:
    """Per-key in-flight dedup: the first caller computes, the rest wait.

    ``begin`` returns (future, owner).  The owner runs the work and must
    call ``finish``/``fail``; non-owners block on ``future.result()``.
    """

    def __init__(self, lock: threading.Lock):
        self._lock = lock            # shared with the owning cache/session
        self._inflight: Dict[Any, Future] = {}

    def begin(self, key: Any) -> Tuple[Future, bool]:
        # caller holds self._lock
        fut = self._inflight.get(key)
        if fut is not None:
            return fut, False
        fut = Future()
        self._inflight[key] = fut
        return fut, True

    def finish(self, key: Any, fut: Future, value: Any) -> None:
        with self._lock:
            self._inflight.pop(key, None)
        fut.set_result(value)

    def fail(self, key: Any, fut: Future, exc: BaseException) -> None:
        with self._lock:
            self._inflight.pop(key, None)
        fut.set_exception(exc)


class _SessionCache:
    """The duck-typed ``ctx.cache`` object pipeline passes consult."""

    def __init__(self, stats: SessionStats,
                 capacity: Optional[int] = None):
        self.stats = stats
        self._lock = threading.Lock()
        self._graphs: LRUCache = LRUCache(capacity)
        self._flight = _SingleFlight(self._lock)

    @property
    def evictions(self) -> int:
        return self._graphs.evictions

    def graph_for(self, module_key: str, module: Module,
                  backend: Backend) -> DependencyGraph:
        key = (module_key, backend.hw.name)
        with self._lock:
            self.stats.graph_requests += 1
            cached = self._graphs.get(key)
            if cached is None:
                fut, owner = self._flight.begin(key)
        if cached is not None:
            # clone OUTSIDE the lock: the pristine copy is never mutated,
            # and cloning a large graph under the lock would serialize
            # every concurrent hit
            return _clone_graph(cached)
        if not owner:
            return _clone_graph(fut.result())
        try:
            with self._lock:
                self.stats.graph_builds += 1
            built = build_dependency_graph(module, backend.hw)
            pristine = _clone_graph(built)   # keep an untouched copy
            with self._lock:
                self._graphs[key] = pristine
        except BaseException as exc:
            self._flight.fail(key, fut, exc)
            raise
        self._flight.finish(key, fut, pristine)
        return built

    def clear(self) -> None:
        with self._lock:
            self._graphs.clear()


ModuleLike = Union[str, Module]


class LeoSession:
    """Cached, thread-safe, multi-backend entry point to LEO's pipeline.

    ::

        session = LeoSession()
        an = session.analyze(hlo_text, backend="tpu_v5e")
        per_vendor = session.compare_backends(hlo_text)   # parses ONCE

    ``parse_cache_size`` / ``graph_cache_size`` / ``analysis_cache_size``
    bound the in-memory tiers (LRU; ``None`` = unbounded, the legacy
    default).  ``disk_cache`` attaches a cross-process on-disk tier for
    parsed modules; :class:`~repro.core.service.LeoService` wires all of
    these with serving-grade defaults.
    """

    def __init__(self, pipeline: Optional[Pipeline] = None,
                 backends: Optional[Sequence[BackendLike]] = None,
                 hints: Optional[dict] = None,
                 default_backend: BackendLike = "tpu_v5e",
                 parse_cache_size: Optional[int] = None,
                 graph_cache_size: Optional[int] = None,
                 analysis_cache_size: Optional[int] = None,
                 disk_cache: Optional[DiskCache] = None):
        self.pipeline = pipeline or DEFAULT_PIPELINE
        # None = live view of the registry (backends registered after the
        # session is constructed still show up in compare_backends).
        self._backends: Optional[List[Backend]] = \
            [resolve_backend(b) for b in backends] \
            if backends is not None else None
        self.hints = hints
        self.default_backend = resolve_backend(default_backend)
        self.stats = SessionStats()
        self.disk_cache = disk_cache
        self._lock = threading.Lock()
        self._modules: LRUCache = LRUCache(
            parse_cache_size, on_evict=self._on_module_evict)
        self._module_keys: Dict[int, str] = {}   # id(Module) -> key
        self._id_seq = 0   # monotonic suffix for identity keys (never reused)
        self._analyses: LRUCache = LRUCache(analysis_cache_size)
        self._cache = _SessionCache(self.stats, graph_cache_size)
        self._parse_flight = _SingleFlight(self._lock)
        self._analyze_flight = _SingleFlight(self._lock)

    def _on_module_evict(self, key: str, module: Module) -> None:
        # drop the id() reverse index so a recycled id cannot alias
        if self._module_keys.get(id(module)) == key:
            del self._module_keys[id(module)]

    @property
    def backends(self) -> List[Backend]:
        return list(self._backends) if self._backends is not None \
            else list_backends()

    @property
    def cache_evictions(self) -> Dict[str, int]:
        return {"parse": self._modules.evictions,
                "graph": self._cache.evictions,
                "analysis": self._analyses.evictions}

    # -- parsing --------------------------------------------------------------

    def module_key(self, hlo_text: str, hints: Optional[dict] = None) -> str:
        h = hashlib.sha256(hlo_text.encode())
        merged = {**(self.hints or {}), **(hints or {})}
        h.update(repr(sorted(merged.items())).encode())
        return h.hexdigest()

    def parse(self, hlo_text: str, hints: Optional[dict] = None) -> Module:
        """Content-hash cached `parse_hlo` (memory -> disk -> parse)."""
        key = self.module_key(hlo_text, hints)
        with self._lock:
            self.stats.parse_calls += 1
            module = self._modules.get(key)
            if module is not None:
                return module
            fut, owner = self._parse_flight.begin(key)
        if not owner:
            return fut.result()
        try:
            module = self.disk_cache.load_module(key) \
                if self.disk_cache is not None else None
            from_disk = module is not None
            if module is None:
                merged = {**(self.hints or {}), **(hints or {})}
                module = parse_hlo(hlo_text, hints=merged or None)
            with self._lock:
                if from_disk:
                    self.stats.parse_disk_hits += 1
                else:
                    self.stats.parse_misses += 1
                self._modules[key] = module
                self._module_keys[id(module)] = key
            if not from_disk and self.disk_cache is not None:
                self.disk_cache.store_module(key, module)
        except BaseException as exc:
            self._parse_flight.fail(key, fut, exc)
            raise
        self._parse_flight.finish(key, fut, module)
        return module

    def _resolve_module(self, program: ModuleLike,
                        hints: Optional[dict]) -> Tuple[Module, str]:
        if isinstance(program, Module):
            # Directly-supplied modules are identity-keyed: the session did
            # not build them and cannot content-hash them cheaply.  The
            # module is retained in the cache so its id() cannot be recycled
            # onto a different Module while the key mapping is live, and the
            # monotonic sequence suffix guarantees a Module whose id IS
            # recycled after LRU eviction still gets a fresh key (its stale
            # analyses can never be hit again).
            with self._lock:
                key = self._module_keys.get(id(program))
                if key is None or self._modules.get(key) is not program:
                    self._id_seq += 1
                    key = f"module-id-{id(program)}-{self._id_seq}"
                    self._module_keys[id(program)] = key
                    self._modules[key] = program
            return program, key
        return self.parse(program, hints), self.module_key(program, hints)

    # -- analysis -------------------------------------------------------------

    def analyze(self, program: ModuleLike, *,
                backend: Optional[BackendLike] = None,
                profile: Optional[StallProfile] = None,
                hints: Optional[dict] = None,
                n_chains: int = 5,
                prune_unexecuted: bool = True) -> LeoAnalysis:
        """Analyze one program (HLO text or pre-parsed Module) on one backend."""
        b = resolve_backend(backend) if backend is not None \
            else self.default_backend
        module, mkey = self._resolve_module(program, hints)
        akey = (mkey, b.name, n_chains, prune_unexecuted)
        with self._lock:
            self.stats.analyze_calls += 1
            if profile is None:
                cached = self._analyses.get(akey)
                if cached is not None:
                    return cached
                fut, owner = self._analyze_flight.begin(akey)
            else:
                fut, owner = None, True   # measured profiles are never cached
        if not owner:
            return fut.result()
        try:
            with self._lock:
                self.stats.analyze_misses += 1
            analysis = self._run_pipeline(module, b, mkey, profile=profile,
                                          n_chains=n_chains,
                                          prune_unexecuted=prune_unexecuted)
            if profile is None:
                with self._lock:
                    self._analyses[akey] = analysis
        except BaseException as exc:
            if fut is not None:
                self._analyze_flight.fail(akey, fut, exc)
            raise
        if fut is not None:
            self._analyze_flight.finish(akey, fut, analysis)
        return analysis

    def _run_pipeline(self, module: Module, backend: Backend, mkey: str,
                      profile: Optional[StallProfile],
                      **options: Any) -> LeoAnalysis:
        import time as _time
        t0 = _time.perf_counter()
        ctx = self.pipeline.run(module, backend, profile=profile,
                                cache=self._cache, module_key=mkey,
                                **options)
        return ctx.to_analysis(analysis_seconds=_time.perf_counter() - t0)

    def analyze_batch(self, programs: Iterable[ModuleLike], *,
                      backend: Optional[BackendLike] = None,
                      **kwargs: Any) -> List[LeoAnalysis]:
        """Fan a set of programs through the cache (e.g. one per pipeline
        stage of a multi-kernel workload).  Serial here; ``LeoService``
        overlays a thread pool."""
        return [self.analyze(p, backend=backend, **kwargs) for p in programs]

    def compare_backends(self, program: ModuleLike, *,
                         backends: Optional[Sequence[BackendLike]] = None,
                         hints: Optional[dict] = None,
                         **kwargs: Any) -> Dict[str, LeoAnalysis]:
        """Observation-1 driver: same program, every backend, parsed once."""
        targets = [resolve_backend(b) for b in backends] \
            if backends is not None else self.backends
        return {b.name: self.analyze(program, backend=b, hints=hints,
                                     **kwargs)
                for b in targets}

    # -- maintenance ----------------------------------------------------------

    def clear_cache(self) -> None:
        with self._lock:
            self._modules.clear()
            self._module_keys.clear()
            self._analyses.clear()
        self._cache.clear()

    def __repr__(self) -> str:
        s = self.stats
        return (f"LeoSession(backends={[b.name for b in self.backends]}, "
                f"modules={len(self._modules)}, analyses={len(self._analyses)}, "
                f"parse {s.parse_hits}/{s.parse_calls} hit)")
