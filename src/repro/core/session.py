"""`LeoSession`: the cached facade over the pass pipeline.

A session owns three content-addressed caches so production callers (the
benchmark harness, a profiling service fanning one trace out to N vendor
models) never re-do work:

  * **parse cache** — HLO text (sha256 + hints) -> parsed ``Module``;
  * **graph cache** — (module, backend) -> pristine dependency graph;
    pipeline passes mutate graphs (sync edges, prune marks), so the cache
    stores an untouched copy and hands out cheap structural clones that
    share ``Instruction``/``PathInfo`` objects but own their ``Edge``s;
  * **analysis cache** — (module, backend, options) -> ``LeoAnalysis``.

``session.stats`` exposes hit/miss counters (asserted by the tier-1 parse-
once test).  ``compare_backends`` is the Observation-1 driver: one parse,
one graph build per backend, N divergent analyses.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

from .backends import Backend, BackendLike, list_backends, resolve_backend
from .depgraph import DependencyGraph, Edge, build_dependency_graph
from .hlo_parser import parse_hlo
from .isa import Module
from .passes import DEFAULT_PIPELINE, LeoAnalysis, Pipeline
from .sampler import StallProfile


@dataclass
class SessionStats:
    parse_calls: int = 0
    parse_misses: int = 0
    graph_requests: int = 0
    graph_builds: int = 0
    analyze_calls: int = 0
    analyze_misses: int = 0

    @property
    def parse_hits(self) -> int:
        return self.parse_calls - self.parse_misses

    @property
    def graph_hits(self) -> int:
        return self.graph_requests - self.graph_builds

    @property
    def analyze_hits(self) -> int:
        return self.analyze_calls - self.analyze_misses

    def as_dict(self) -> Dict[str, int]:
        return {
            "parse_calls": self.parse_calls, "parse_hits": self.parse_hits,
            "graph_requests": self.graph_requests,
            "graph_hits": self.graph_hits,
            "analyze_calls": self.analyze_calls,
            "analyze_hits": self.analyze_hits,
        }


def _clone_graph(graph: DependencyGraph) -> DependencyGraph:
    """Structural clone: shares the Module and per-edge PathInfo objects
    (immutable), owns the Edge records and index lists (mutated by the
    sync/prune passes)."""
    clone = DependencyGraph(module=graph.module)
    for e in graph.edges:
        clone.add(Edge(producer=e.producer, consumer=e.consumer, kind=e.kind,
                       paths=list(e.paths), pruned_by=e.pruned_by))
    return clone


class _SessionCache:
    """The duck-typed ``ctx.cache`` object pipeline passes consult."""

    def __init__(self, stats: SessionStats):
        self.stats = stats
        self._graphs: Dict[Tuple[str, str], DependencyGraph] = {}

    def graph_for(self, module_key: str, module: Module,
                  backend: Backend) -> DependencyGraph:
        self.stats.graph_requests += 1
        key = (module_key, backend.hw.name)
        cached = self._graphs.get(key)
        if cached is None:
            self.stats.graph_builds += 1
            cached = build_dependency_graph(module, backend.hw)
            self._graphs[key] = _clone_graph(cached)  # keep a pristine copy
            return cached
        return _clone_graph(cached)

    def clear(self) -> None:
        self._graphs.clear()


ModuleLike = Union[str, Module]


class LeoSession:
    """Cached, multi-backend entry point to LEO's analysis pipeline.

    ::

        session = LeoSession()
        an = session.analyze(hlo_text, backend="tpu_v5e")
        per_vendor = session.compare_backends(hlo_text)   # parses ONCE
    """

    def __init__(self, pipeline: Optional[Pipeline] = None,
                 backends: Optional[Sequence[BackendLike]] = None,
                 hints: Optional[dict] = None,
                 default_backend: BackendLike = "tpu_v5e"):
        self.pipeline = pipeline or DEFAULT_PIPELINE
        # None = live view of the registry (backends registered after the
        # session is constructed still show up in compare_backends).
        self._backends: Optional[List[Backend]] = \
            [resolve_backend(b) for b in backends] \
            if backends is not None else None
        self.hints = hints
        self.default_backend = resolve_backend(default_backend)
        self.stats = SessionStats()
        self._modules: Dict[str, Module] = {}
        self._module_keys: Dict[int, str] = {}   # id(Module) -> key
        self._analyses: Dict[Tuple, LeoAnalysis] = {}
        self._cache = _SessionCache(self.stats)

    @property
    def backends(self) -> List[Backend]:
        return list(self._backends) if self._backends is not None \
            else list_backends()

    # -- parsing --------------------------------------------------------------

    def module_key(self, hlo_text: str, hints: Optional[dict] = None) -> str:
        h = hashlib.sha256(hlo_text.encode())
        merged = {**(self.hints or {}), **(hints or {})}
        h.update(repr(sorted(merged.items())).encode())
        return h.hexdigest()

    def parse(self, hlo_text: str, hints: Optional[dict] = None) -> Module:
        """Content-hash cached `parse_hlo`."""
        self.stats.parse_calls += 1
        key = self.module_key(hlo_text, hints)
        module = self._modules.get(key)
        if module is None:
            self.stats.parse_misses += 1
            merged = {**(self.hints or {}), **(hints or {})}
            module = parse_hlo(hlo_text, hints=merged or None)
            self._modules[key] = module
            self._module_keys[id(module)] = key
        return module

    def _resolve_module(self, program: ModuleLike,
                        hints: Optional[dict]) -> Tuple[Module, str]:
        if isinstance(program, Module):
            # Directly-supplied modules are identity-keyed: the session did
            # not build them and cannot content-hash them cheaply.  The
            # module is retained in the cache so its id() cannot be recycled
            # onto a different Module while the key mapping is live.
            key = self._module_keys.get(id(program))
            if key is None or self._modules.get(key) is not program:
                key = f"module-id-{id(program)}-{len(self._modules)}"
                self._module_keys[id(program)] = key
                self._modules[key] = program
            return program, key
        return self.parse(program, hints), self.module_key(program, hints)

    # -- analysis -------------------------------------------------------------

    def analyze(self, program: ModuleLike, *,
                backend: Optional[BackendLike] = None,
                profile: Optional[StallProfile] = None,
                hints: Optional[dict] = None,
                n_chains: int = 5,
                prune_unexecuted: bool = True) -> LeoAnalysis:
        """Analyze one program (HLO text or pre-parsed Module) on one backend."""
        self.stats.analyze_calls += 1
        b = resolve_backend(backend) if backend is not None \
            else self.default_backend
        module, mkey = self._resolve_module(program, hints)
        akey = (mkey, b.name, n_chains, prune_unexecuted)
        if profile is None:
            cached = self._analyses.get(akey)
            if cached is not None:
                return cached
        self.stats.analyze_misses += 1
        import time as _time
        t0 = _time.perf_counter()
        ctx = self.pipeline.run(module, b, profile=profile,
                                cache=self._cache, module_key=mkey,
                                n_chains=n_chains,
                                prune_unexecuted=prune_unexecuted)
        analysis = ctx.to_analysis(analysis_seconds=_time.perf_counter() - t0)
        if profile is None:
            self._analyses[akey] = analysis
        return analysis

    def analyze_batch(self, programs: Iterable[ModuleLike], *,
                      backend: Optional[BackendLike] = None,
                      **kwargs: Any) -> List[LeoAnalysis]:
        """Fan a set of programs through the cache (e.g. one per pipeline
        stage of a multi-kernel workload)."""
        return [self.analyze(p, backend=backend, **kwargs) for p in programs]

    def compare_backends(self, program: ModuleLike, *,
                         backends: Optional[Sequence[BackendLike]] = None,
                         hints: Optional[dict] = None,
                         **kwargs: Any) -> Dict[str, LeoAnalysis]:
        """Observation-1 driver: same program, every backend, parsed once."""
        targets = [resolve_backend(b) for b in backends] \
            if backends is not None else self.backends
        return {b.name: self.analyze(program, backend=b, hints=hints,
                                     **kwargs)
                for b in targets}

    # -- maintenance ----------------------------------------------------------

    def clear_cache(self) -> None:
        self._modules.clear()
        self._module_keys.clear()
        self._analyses.clear()
        self._cache.clear()

    def __repr__(self) -> str:
        s = self.stats
        return (f"LeoSession(backends={[b.name for b in self.backends]}, "
                f"modules={len(self._modules)}, analyses={len(self._analyses)}, "
                f"parse {s.parse_hits}/{s.parse_calls} hit)")
