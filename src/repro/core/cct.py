"""Calling-context tree (CCT) over scoped op_name metadata.

HPCToolkit organizes a kernel's instructions into a CCT spanning device
functions, inlined templates, loops and statements (paper §III-B).  The XLA
analogue: JAX embeds the full traced call path in each HLO instruction's
``metadata op_name`` (e.g. ``jit(train_step)/while/body/decoder/layer/attn/
qk_matmul``) — model-library scopes play the role of source files, which is
what makes Kripke-style "the root cause is three framework layers away"
diagnoses possible (§VI-E).

The CCT aggregates per-instruction samples/stall cycles bottom-up so reports
can show per-layer / per-module hot paths.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .isa import Instruction, Module
from .sampler import StallProfile


@dataclass
class CCTNode:
    name: str
    path: Tuple[str, ...]
    children: Dict[str, "CCTNode"] = field(default_factory=dict)
    instructions: List[str] = field(default_factory=list)  # qualified names
    stall_cycles: float = 0.0
    total_samples: float = 0.0

    def child(self, name: str) -> "CCTNode":
        if name not in self.children:
            self.children[name] = CCTNode(name=name, path=self.path + (name,))
        return self.children[name]

    def walk(self):
        yield self
        for c in self.children.values():
            yield from c.walk()

    def hot_path(self) -> List["CCTNode"]:
        """Descend along the highest-stall child at each level."""
        path = [self]
        node = self
        while node.children:
            node = max(node.children.values(), key=lambda c: c.stall_cycles)
            if node.stall_cycles <= 0:
                break
            path.append(node)
        return path


def build_cct(module: Module, profile: Optional[StallProfile] = None) -> CCTNode:
    root = CCTNode(name="<root>", path=())
    for instr in module.all_instructions():
        scope = instr.scope_path()
        node = root
        for part in scope:
            node = node.child(part)
        node.instructions.append(instr.qualified_name)
        if profile is not None:
            rec = profile.records.get(instr.qualified_name)
            if rec is not None:
                # accumulate up the path
                cur = root
                cur.stall_cycles += rec.latency_samples
                cur.total_samples += rec.total_samples
                for part in scope:
                    cur = cur.children[part]
                    cur.stall_cycles += rec.latency_samples
                    cur.total_samples += rec.total_samples
    return root


def format_hot_path(root: CCTNode, limit: int = 12) -> str:
    lines = []
    for i, node in enumerate(root.hot_path()[:limit]):
        pct = 100.0 * node.stall_cycles / max(root.stall_cycles, 1e-12)
        lines.append(f"{'  ' * i}{node.name or '<root>'}  "
                     f"[{node.stall_cycles:,.0f} stall cyc, {pct:.1f}%]")
    return "\n".join(lines)
