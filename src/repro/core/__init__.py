"""LEO core: cross-backend stall root-cause analysis via backward slicing.

The public API has four layers (see ``docs/api.md`` for a tour):

**Service** — the serving-grade entry point: typed ``AnalyzeRequest`` in,
serializable ``Diagnosis`` out, bounded LRU + on-disk caches, concurrent
multi-backend fan-out over a thread pool::

    from repro.core import AnalyzeRequest, LeoService
    svc = LeoService(cache_dir=".leo_cache")
    diag = svc.diagnose(hlo_text, backend="tpu_v5e")       # Diagnosis
    diag.to_json(); diag.to_markdown(); diag.to_llm_context("C+L(S)")
    svc.submit(AnalyzeRequest(hlo_text=hlo_text))          # queue shape

**Sessions** — the cached facade underneath (raw ``LeoAnalysis`` out).
Parses each HLO text once (content-hash cache), builds each (module,
backend) dependency graph once, and memoizes whole analyses; thread-safe
with single-flight cache fills::

    from repro.core import LeoSession
    session = LeoSession()
    an = session.analyze(hlo_text, backend="tpu_v5e")      # LeoAnalysis
    per_vendor = session.compare_backends(hlo_text)        # parses ONCE

**Backends** — a pluggable registry of vendor descriptors (hardware model +
native stall taxonomy + sync-semantics knobs).  Six ship by default: three
TPU generations and NVIDIA/AMD/Intel-class parts; third parties add more
without touching core files::

    from repro.core import Backend, get_backend, list_backends, register_backend
    register_backend(Backend(name="my_asic", vendor="acme", hw=..., ...))

**Pipeline** — the named, reorderable analysis passes behind every entry
point (sample -> depgraph -> coverage -> sync_edges -> prune -> blame ->
chains -> cct).  Derive variants to insert/remove/replace passes::

    from repro.core import default_pipeline
    pipe = default_pipeline().without("cct")
    ctx = pipe.run(module, "nvidia_gh200")     # raw AnalysisContext
    # (pipe.analyze() needs every LeoAnalysis artifact, so trimmed
    #  pipelines are consumed via run(); the full default supports both)

Legacy one-shot helpers (``analyze_hlo`` / ``analyze_module`` /
``cross_backend_analyze``) remain as thin shims over the same pipeline.
"""
from .analyzer import (
    LeoAnalysis,
    analyze_hlo,
    analyze_module,
    cross_backend_analyze,
)
from .caching import DiskCache, LRUCache
from .backends import (
    Backend,
    BackendRegistry,
    DEFAULT_SYNC_MODEL,
    REGISTRY,
    SyncModel,
    SyncPressureReport,
    SyncResourcePool,
    SyncScoreboard,
    SyncSemantics,
    UnknownBackendError,
    get_backend,
    list_backends,
    register_backend,
    resolve_backend,
    resolve_sync_model,
)
from .blame import (
    BlameResult,
    SchedulerContentionBlame,
    SyncResourceBlame,
    attribute_blame,
)
from .cct import build_cct, format_hot_path
from .collectives import (
    collective_operand_bytes,
    collective_summary,
    total_collective_bytes,
)
from .coverage import single_dependency_coverage
from .depgraph import DependencyGraph, Edge, build_dependency_graph
from .hlo_parser import HloParser, parse_hlo
from .hwmodel import (
    HARDWARE_MODELS,
    SINGLE_ISSUE,
    SINGLE_WAVE,
    TPU_V4,
    TPU_V5E,
    TPU_V5P,
    HardwareModel,
    IssueModel,
    OccupancyModel,
    get_hardware_model,
)
from .isa import (
    Computation,
    EdgeKind,
    Instruction,
    Module,
    OpClass,
    ShapeInfo,
    StallClass,
    SyncKind,
)
from .jaxpr_frontend import from_function, from_jaxpr
from .passes import (
    AnalysisContext,
    AnalysisPass,
    DEFAULT_PIPELINE,
    IncompletePipelineError,
    Pipeline,
    PipelineOrderError,
    default_pipeline,
)
from .pruning import prune
from .report import (
    ADVICE_NOT_RECORDED,
    MIN_SCHEMA_VERSION,
    SCHEMA_VERSION,
    Diagnosis,
    Recommendation,
    diagnostic_context,
    recommendations,
    save_json,
    structured_report,
)
from .roofline import RooflineReport, compute_roofline
from .sampler import (
    IssuePressureReport,
    StallProfile,
    VirtualSampler,
    sample,
)
from .service import AnalyzeRequest, DiagnoseOptions, LeoService
from .session import LeoSession, SessionStats
from .slicing import StallChain, top_chains
from .sync_trace import add_sync_edges

__all__ = [
    # service surface (typed requests / serializable diagnoses)
    "AnalyzeRequest", "DiagnoseOptions", "Diagnosis", "LeoService",
    "Recommendation",
    "ADVICE_NOT_RECORDED", "MIN_SCHEMA_VERSION", "SCHEMA_VERSION",
    # cache tiers
    "DiskCache", "LRUCache",
    # session facade
    "LeoSession", "SessionStats",
    # backend registry + sync resources + issue model
    "Backend", "BackendRegistry", "DEFAULT_SYNC_MODEL", "REGISTRY",
    "IssueModel", "IssuePressureReport", "SINGLE_ISSUE",
    "OccupancyModel", "SINGLE_WAVE",
    "SchedulerContentionBlame",
    "SyncModel", "SyncPressureReport", "SyncResourceBlame",
    "SyncResourcePool", "SyncScoreboard", "SyncSemantics",
    "UnknownBackendError", "get_backend", "list_backends",
    "register_backend", "resolve_backend", "resolve_sync_model",
    # pass pipeline
    "AnalysisContext", "AnalysisPass", "DEFAULT_PIPELINE",
    "IncompletePipelineError", "Pipeline", "PipelineOrderError",
    "default_pipeline",
    # legacy shims + result object
    "LeoAnalysis", "analyze_hlo", "analyze_module", "cross_backend_analyze",
    # phase primitives
    "BlameResult", "attribute_blame", "build_cct", "format_hot_path",
    "collective_operand_bytes", "collective_summary", "total_collective_bytes",
    "single_dependency_coverage", "DependencyGraph", "Edge",
    "build_dependency_graph", "HloParser", "parse_hlo", "HARDWARE_MODELS",
    "TPU_V4", "TPU_V5E", "TPU_V5P", "HardwareModel", "get_hardware_model",
    "Computation", "EdgeKind", "Instruction", "Module", "OpClass",
    "ShapeInfo", "StallClass", "SyncKind", "from_function", "from_jaxpr",
    "prune", "diagnostic_context", "recommendations", "save_json",
    "structured_report", "RooflineReport", "compute_roofline", "StallProfile",
    "VirtualSampler", "sample", "StallChain", "top_chains", "add_sync_edges",
]
