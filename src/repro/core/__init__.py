"""LEO core: cross-backend stall root-cause analysis via backward slicing.

Public API:

    from repro.core import analyze_hlo, analyze_module, cross_backend_analyze
    from repro.core import from_function            # jaxpr/Pallas front-end
    from repro.core import compute_roofline, TPU_V5E
"""
from .analyzer import (
    LeoAnalysis,
    analyze_hlo,
    analyze_module,
    cross_backend_analyze,
)
from .blame import BlameResult, attribute_blame
from .cct import build_cct, format_hot_path
from .collectives import (
    collective_operand_bytes,
    collective_summary,
    total_collective_bytes,
)
from .coverage import single_dependency_coverage
from .depgraph import DependencyGraph, Edge, build_dependency_graph
from .hlo_parser import HloParser, parse_hlo
from .hwmodel import (
    HARDWARE_MODELS,
    TPU_V4,
    TPU_V5E,
    TPU_V5P,
    HardwareModel,
    get_hardware_model,
)
from .isa import (
    Computation,
    EdgeKind,
    Instruction,
    Module,
    OpClass,
    ShapeInfo,
    StallClass,
    SyncKind,
)
from .jaxpr_frontend import from_function, from_jaxpr
from .pruning import prune
from .report import (
    diagnostic_context,
    recommendations,
    save_json,
    structured_report,
)
from .roofline import RooflineReport, compute_roofline
from .sampler import StallProfile, VirtualSampler, sample
from .slicing import StallChain, top_chains
from .sync_trace import add_sync_edges

__all__ = [
    "LeoAnalysis", "analyze_hlo", "analyze_module", "cross_backend_analyze",
    "BlameResult", "attribute_blame", "build_cct", "format_hot_path",
    "collective_operand_bytes", "collective_summary", "total_collective_bytes",
    "single_dependency_coverage", "DependencyGraph", "Edge",
    "build_dependency_graph", "HloParser", "parse_hlo", "HARDWARE_MODELS",
    "TPU_V4", "TPU_V5E", "TPU_V5P", "HardwareModel", "get_hardware_model",
    "Computation", "EdgeKind", "Instruction", "Module", "OpClass",
    "ShapeInfo", "StallClass", "SyncKind", "from_function", "from_jaxpr",
    "prune", "diagnostic_context", "recommendations", "save_json",
    "structured_report", "RooflineReport", "compute_roofline", "StallProfile",
    "VirtualSampler", "sample", "StallChain", "top_chains", "add_sync_edges",
]
