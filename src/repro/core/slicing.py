"""Backward slice / ranked dependency-chain extraction (§III, Fig. 7).

Starting from the top-stalled instructions, walk backward over surviving
edges following the highest-blame contributions, producing ranked chains of
the Fig.-7 form:

    DFMA        LTimes.cpp:62          96.7% stall cycles
    ^ LDG.E.64  LTimes.cpp:62          global load (stalled)
    ^ LEA.HI.X  TypedViewBase.hpp:216  array index
    ...

Each link carries the instruction, the edge kind that led to it, the blame
cycles flowing along that edge, and the op_name scope — which is what lets a
chain cross framework layers (model-library scopes play the role of RAJA
header files in the paper's Kripke case study).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from .blame import BlameResult
from .depgraph import DependencyGraph
from .isa import EdgeKind, Instruction
from .sampler import StallProfile


@dataclass
class ChainLink:
    qualified: str
    opcode: str
    edge_kind: Optional[EdgeKind]   # edge that led here (None for the head)
    blame_cycles: float
    op_name: str = ""
    source: str = ""                # "file:line" when available

    def describe(self) -> str:
        arrow = "" if self.edge_kind is None else f"^ [{self.edge_kind.value}] "
        loc = self.source or self.op_name or "?"
        return f"{arrow}{self.opcode:<24s} {loc}  ({self.blame_cycles:,.0f} cyc)"


@dataclass
class StallChain:
    links: List[ChainLink] = field(default_factory=list)
    total_stall_cycles: float = 0.0   # stall at the head (symptom)

    @property
    def head(self) -> ChainLink:
        return self.links[0]

    @property
    def root(self) -> ChainLink:
        return self.links[-1]

    @property
    def score(self) -> float:
        return self.root.blame_cycles

    def describe(self) -> str:
        return "\n".join(("  " * i) + l.describe()
                         for i, l in enumerate(self.links))


def _source_of(instr: Optional[Instruction]) -> str:
    if instr is None:
        return ""
    if instr.source_file:
        return f"{instr.source_file}:{instr.source_line}"
    return ""


class Slicer:
    def __init__(self, graph: DependencyGraph, profile: StallProfile,
                 blame: BlameResult, max_depth: int = 8):
        self.graph = graph
        self.profile = profile
        self.blame = blame
        self.max_depth = max_depth
        # (producer, consumer) -> cycles for fast chain extension
        self._contrib: Dict[str, List] = {}
        for entry in blame.entries:
            self._contrib.setdefault(entry.consumer, []).append(entry)
        for v in self._contrib.values():
            v.sort(key=lambda e: -e.cycles)

    def top_chains(self, n_chains: int = 5,
                   branch_width: int = 2) -> List[StallChain]:
        chains: List[StallChain] = []
        for rec in self.profile.top_stalled(n_chains * 2):
            instr = self.graph.instruction(rec.qualified)
            head = ChainLink(
                qualified=rec.qualified,
                opcode=instr.opcode if instr else "?",
                edge_kind=None,
                blame_cycles=rec.latency_samples,
                op_name=instr.op_name if instr else "",
                source=_source_of(instr))
            for chain in self._extend(head, rec.latency_samples,
                                      {rec.qualified}, 0, branch_width):
                chain.total_stall_cycles = rec.latency_samples
                chains.append(chain)
        chains.sort(key=lambda c: -c.score)
        return chains[:n_chains]

    def _extend(self, link: ChainLink, flow: float, visited: Set[str],
                depth: int, branch_width: int) -> List[StallChain]:
        contribs = [e for e in self._contrib.get(link.qualified, [])
                    if e.producer not in visited]
        if depth >= self.max_depth or not contribs:
            return [StallChain(links=[link])]
        out: List[StallChain] = []
        for entry in contribs[:branch_width]:
            producer = self.graph.instruction(entry.producer)
            nxt = ChainLink(
                qualified=entry.producer,
                opcode=producer.opcode if producer else "?",
                edge_kind=entry.kind,
                blame_cycles=entry.cycles,
                op_name=producer.op_name if producer else "",
                source=_source_of(producer))
            for sub in self._extend(nxt, entry.cycles,
                                    visited | {entry.producer},
                                    depth + 1, 1):
                out.append(StallChain(links=[link] + sub.links))
        return out or [StallChain(links=[link])]


def top_chains(graph: DependencyGraph, profile: StallProfile,
               blame: BlameResult, n: int = 5) -> List[StallChain]:
    return Slicer(graph, profile, blame).top_chains(n)
