"""Analytical hardware models — the cross-"vendor" axis of the adaptation.

The paper analyzes the same kernel on NVIDIA GH200, AMD MI300A and Intel PVC
and shows the *same source* exhibits *different* bottlenecks per platform
(Observation 1).  Our backend axis is TPU generations with materially
different FLOP:HBM:ICI ratios — v5e (cost-optimized, narrow HBM), v5p
(training flagship, fat HBM + ICI) and v4 — so a kernel that is
collective-bound on v5e can be compute-bound on v5p, reproducing the paper's
cross-platform divergence with TPU-native semantics.

All roofline and stall-cycle arithmetic in `sampler.py` / `roofline.py` is
parameterized by one of these models; `TPU_V5E` carries the constants the
deliverable mandates (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

from .isa import Instruction, OpClass

#: Scheduler policies an :class:`IssueModel` can declare.
ISSUE_POLICIES: Tuple[str, ...] = ("round_robin", "greedy_oldest")


@dataclass(frozen=True)
class IssueModel:
    """Per-vendor issue-stream model (the multi-stream sampler's contract).

    ``queues``  — concurrent issue queues (warp schedulers on NVIDIA-class
                  parts, SIMD units per CU on AMD-class parts, Xe vector
                  engines on Intel-class parts; 1 = the in-order VLIW
                  single stream of a TPU core).
    ``width``   — issue slots per queue (co-issue ports).
    ``policy``  — how ready instructions map onto queues:
                  ``round_robin``   static cyclic assignment (AMD's SIMD
                                    rotation; an instruction waits for
                                    *its* queue even if others are idle);
                  ``greedy_oldest`` work-conserving greedy-then-oldest
                                    arbitration (NVIDIA GTO): an
                                    instruction waits only when every
                                    queue is busy.

    With ``ports == 1`` the sampler degenerates *byte-identically* to the
    single-stream in-order model (the parity anchor for every pre-existing
    golden): a lone in-order stream has no arbitration, so no
    ``not_selected`` / ``pipe_busy`` samples are ever charged.
    """

    queues: int = 1
    width: int = 1
    policy: str = "round_robin"

    def __post_init__(self) -> None:
        if self.queues < 1:
            raise ValueError(f"queues must be >= 1, got {self.queues}")
        if self.width < 1:
            raise ValueError(f"width must be >= 1, got {self.width}")
        if self.policy not in ISSUE_POLICIES:
            raise ValueError(
                f"unknown issue policy {self.policy!r}; known: "
                f"{ISSUE_POLICIES}")

    @property
    def ports(self) -> int:
        """Total concurrent issue slots (queues x width)."""
        return self.queues * self.width

    @property
    def multi_stream(self) -> bool:
        return self.ports > 1


#: The degenerate single-stream model: one in-order queue, one slot.
SINGLE_ISSUE = IssueModel(queues=1, width=1, policy="round_robin")


#: Residency limiters an :class:`OccupancyModel` can declare — the
#: vendor-specific budget that bounds resident waves per issue queue.
OCCUPANCY_LIMITERS: Tuple[str, ...] = (
    "none",             # single resident wave (no hiding beyond async retire)
    "register_file",    # NVIDIA-style: register allocation caps warps/SM
    "wavefront_slots",  # AMD-style: wave slots per SIMD (VGPR/LDS budget)
    "thread_slots",     # Intel-style: hardware threads per Xe vector engine
)


@dataclass(frozen=True)
class OccupancyModel:
    """Per-vendor wave-residency model (failed-latency-hiding contract).

    ``waves``  — resident waves per issue queue (warps per scheduler on
                 NVIDIA-class parts, wavefront slots per SIMD on AMD-class
                 parts, hardware threads per Xe vector engine on
                 Intel-class parts; 1 = a TPU core's lone program).
    ``limiter`` — which vendor budget bounds ``waves`` (see
                 :data:`OCCUPANCY_LIMITERS`); advisory metadata that also
                 drives vendor-native advisor phrasing.
    ``window_cycles`` — per-wave cap on banked latency-hiding credit: a
                 co-resident wave can cover at most this many stall cycles
                 before it, too, runs out of independent work (the
                 ILP-per-wave horizon).  Per-vendor divergence knob.

    With ``waves == 1`` the sampler bypasses the occupancy machinery
    entirely and degenerates *byte-identically* to the single-wave model —
    the parity anchor for every pre-occupancy golden (same trick as
    ``IssueModel.ports == 1``).
    """

    waves: int = 1
    limiter: str = "none"
    window_cycles: float = 64.0

    def __post_init__(self) -> None:
        if self.waves < 1:
            raise ValueError(f"waves must be >= 1, got {self.waves}")
        if self.limiter not in OCCUPANCY_LIMITERS:
            raise ValueError(
                f"unknown occupancy limiter {self.limiter!r}; known: "
                f"{OCCUPANCY_LIMITERS}")
        if self.window_cycles <= 0:
            raise ValueError(
                f"window_cycles must be > 0, got {self.window_cycles}")

    @property
    def multi_wave(self) -> bool:
        return self.waves > 1


#: The degenerate residency model: one wave, no latency-hiding credit.
SINGLE_WAVE = OccupancyModel(waves=1, limiter="none")


@dataclass(frozen=True)
class HardwareModel:
    name: str
    # Peak compute, per chip.
    peak_flops_bf16: float          # FLOP/s
    peak_flops_f32: float           # FLOP/s (VPU-bound path)
    hbm_bw: float                   # bytes/s
    hbm_bytes: int                  # capacity per chip
    ici_bw_per_link: float          # bytes/s per link, per direction
    ici_links: int                  # usable links per chip (torus degree)
    vmem_bytes: int                 # on-chip vector memory
    clock_hz: float                 # core clock used to convert seconds->cycles
    issue_overhead_cycles: float    # per-instruction scheduler issue cost
    dma_setup_cycles: float         # HBM<->VMEM DMA setup latency
    collective_setup_cycles: float  # per-collective launch latency
    mxu_pipe_depth_cycles: float = 64.0   # systolic-array fill/drain latency
    vpu_pipe_depth_cycles: float = 16.0   # vector-unit pipeline latency
    # Cost to recycle an exhausted synchronization resource (§III-E): when a
    # kernel holds more async transfers in flight than the part has barrier
    # slots / waitcnt counters / SWSB tokens, the oversubscribing
    # instruction serializes against the oldest holder and pays this
    # additional drain/re-arm latency on top of the holder's remaining time.
    sync_realloc_cycles: float = 4.0
    # Concurrent issue-queue model driving the multi-stream sampler; the
    # default is the degenerate single in-order stream.
    issue: IssueModel = field(default=SINGLE_ISSUE)
    # Resident-wave model driving the latency-hiding sampler; the default
    # is the degenerate single wave (every registered backend keeps this —
    # native residency lives on `Backend.native_occupancy` and is engaged
    # via `Backend.with_occupancy()` so plain profiles stay byte-identical).
    occupancy: OccupancyModel = field(default=SINGLE_WAVE)

    @property
    def ici_bw_total(self) -> float:
        return self.ici_bw_per_link * self.ici_links

    # --- per-instruction latency model (virtual PC sampling input) ---------

    def compute_seconds(self, instr: Instruction) -> float:
        if instr.flops <= 0:
            return 0.0
        peak = self.peak_flops_bf16 if instr.op_class is OpClass.MATMUL \
            else self.peak_flops_f32
        # VPU elementwise work rarely reaches peak; keep a flat derate.
        derate = 1.0 if instr.op_class is OpClass.MATMUL else 0.5
        return instr.flops / (peak * derate)

    def memory_seconds(self, instr: Instruction) -> float:
        bytes_moved = instr.bytes_read + instr.bytes_written
        if bytes_moved <= 0:
            return 0.0
        return bytes_moved / self.hbm_bw

    def collective_seconds(self, instr: Instruction) -> float:
        if instr.comm_bytes <= 0:
            return 0.0
        return instr.comm_bytes / self.ici_bw_per_link \
            + self.collective_setup_cycles / self.clock_hz

    def latency_seconds(self, instr: Instruction) -> float:
        """Roofline latency of one instruction: max of its resource terms."""
        return max(self.compute_seconds(instr), self.memory_seconds(instr),
                   self.collective_seconds(instr))

    def latency_cycles(self, instr: Instruction) -> float:
        """Issue-to-result latency: when the value becomes consumable.

        Compute units have pipeline depth beyond their throughput occupancy
        (systolic fill/drain on the MXU, vector pipeline on the VPU), so a
        dependent consumer issued back-to-back stalls by that depth — the
        TPU analogue of the paper's DMUL->DMUL execution-dependency chains.
        """
        base = self.issue_overhead_cycles
        if instr.op_class in (OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE,
                              OpClass.DATA_MOVEMENT, OpClass.SYNC_SET):
            base += self.dma_setup_cycles
        elif instr.op_class is OpClass.MATMUL:
            base += self.mxu_pipe_depth_cycles
        elif instr.op_class in (OpClass.COMPUTE, OpClass.REDUCE,
                                OpClass.FUSION):
            base += self.vpu_pipe_depth_cycles
        elif instr.op_class is OpClass.COLLECTIVE:
            base += self.collective_setup_cycles
        return base + self.latency_seconds(instr) * self.clock_hz

    def issue_cycles(self, instr: Instruction) -> float:
        """Cycles the instruction occupies the issue slot (throughput cost).

        This plays the role of `control.stall` (NVIDIA) / instruction counts
        (AMD/Intel) in the paper's Stage-3 latency pruning: work issued
        between a producer and its consumer hides the producer's latency.

        Memory traffic, async copies and async collective *starts* retire
        from the issue slot after DMA setup and complete in the background
        (the TPU analogue of warp-level latency hiding): their latency is
        only *exposed* if a consumer catches up with them.  Compute ops
        occupy their pipeline for their full throughput cost.  Synchronous
        collectives block the stream.
        """
        if instr.op_class in (OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE,
                              OpClass.DATA_MOVEMENT, OpClass.SYNC_SET):
            return self.issue_overhead_cycles + self.dma_setup_cycles
        if instr.op_class is OpClass.COLLECTIVE:
            # Collectives launch asynchronously onto the ICI DMA engines;
            # their transfer latency is exposed at the *consumer* (this is
            # what produces collective_wait stalls for LEO to trace).
            return self.issue_overhead_cycles + self.collective_setup_cycles
        if instr.op_class in (OpClass.SYNC_WAIT, OpClass.TUPLE,
                              OpClass.PARAMETER, OpClass.CONSTANT):
            return self.issue_overhead_cycles
        # COMPUTE / MATMUL / REDUCE / FUSION / CONTROL: the op occupies its
        # unit for its full roofline (throughput) time.
        return self.issue_overhead_cycles + self.latency_seconds(instr) * self.clock_hz


# TPU cores are in-order VLIW: the compiler schedules one bundle stream,
# so the issue model is the degenerate single queue (scheduler-contention
# stalls structurally cannot occur — the compiler already serialized).
TPU_ISSUE = SINGLE_ISSUE

TPU_V5E = HardwareModel(
    name="tpu_v5e",
    issue=TPU_ISSUE,
    peak_flops_bf16=197e12,
    peak_flops_f32=98.5e12,
    hbm_bw=819e9,
    hbm_bytes=16 * 2**30,
    ici_bw_per_link=50e9,
    ici_links=4,
    vmem_bytes=128 * 2**20,
    clock_hz=940e6,
    issue_overhead_cycles=1.0,
    dma_setup_cycles=8.0,
    collective_setup_cycles=2000.0,
)

TPU_V5P = HardwareModel(
    name="tpu_v5p",
    issue=TPU_ISSUE,
    peak_flops_bf16=459e12,
    peak_flops_f32=229.5e12,
    hbm_bw=2765e9,
    hbm_bytes=95 * 2**30,
    ici_bw_per_link=100e9,
    ici_links=6,
    vmem_bytes=128 * 2**20,
    clock_hz=1750e6,
    issue_overhead_cycles=1.0,
    dma_setup_cycles=8.0,
    collective_setup_cycles=2000.0,
)

TPU_V4 = HardwareModel(
    name="tpu_v4",
    issue=TPU_ISSUE,
    peak_flops_bf16=275e12,
    peak_flops_f32=137.5e12,
    hbm_bw=1228e9,
    hbm_bytes=32 * 2**30,
    ici_bw_per_link=50e9,
    ici_links=6,
    vmem_bytes=128 * 2**20,
    clock_hz=1050e6,
    issue_overhead_cycles=1.0,
    dma_setup_cycles=8.0,
    collective_setup_cycles=2000.0,
)

HARDWARE_MODELS: Dict[str, HardwareModel] = {
    m.name: m for m in (TPU_V5E, TPU_V5P, TPU_V4)
}


def get_hardware_model(name: str) -> HardwareModel:
    try:
        return HARDWARE_MODELS[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware model {name!r}; known: {sorted(HARDWARE_MODELS)}")
