"""HLO-text front-end: optimized HLO module text -> unified `Module`.

This is LEO's "disassembler" for the XLA backend (paper §III-A phase 1/2:
nvdisasm / llvm-objdump / GED).  It parses the post-optimization,
post-SPMD-partitioning HLO emitted by ``compiled.as_text()`` — shapes are
therefore *per-device* shards, which is exactly what per-chip roofline and
stall analysis need — and annotates every instruction with:

  * opcode class (for Stage-1 opcode pruning),
  * analytical FLOPs / HBM bytes / collective bytes (virtual PC sampling),
  * source attribution from ``metadata={op_name=... source_file=...}``
    (the DWARF analogue: this is what lets chains cross framework layers),
  * synchronization semantics for async start/done pairs (§III-E).

The parser is intentionally tolerant: unknown attributes are kept verbatim,
unknown opcodes classify as COMPUTE, so new XLA versions degrade gracefully
instead of failing (the paper's "ISA tables must evolve" limitation).
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

from .isa import (
    Computation,
    Instruction,
    Module,
    OpClass,
    ShapeInfo,
    SyncInfo,
    SyncKind,
    classify_opcode,
)

# Opcodes whose "operand" text is a literal, not instruction references.
_LITERAL_OPERAND_OPCODES = {"constant", "parameter"}

_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "power", "tanh", "sine", "cosine", "atan2", "erf", "logistic",
    "cbrt", "expm1",
}

_COMP_HEADER_RE = re.compile(
    r"^\s*(?P<entry>ENTRY\s+)?%?(?P<name>[^\s(]+)\s*\((?P<params>.*)\)\s*->")

# frontend_attributes={sync_tag="..."}: the sync identifier override the
# CoalesceSyncTags rewrite lowers to (see `_annotate_sync`).
_SYNC_TAG_RE = re.compile(r'sync_tag="([^"]*)"')
_INSTR_RE = re.compile(
    r"^\s*(?P<root>ROOT\s+)?%?(?P<name>[^\s=]+)\s*=\s*(?P<rest>.+)$")


def _split_top_level(s: str, sep: str = ",") -> List[str]:
    """Split on `sep` at nesting depth 0 (w.r.t. (), [], {}, and quotes)."""
    parts: List[str] = []
    depth = 0
    in_str = False
    cur: List[str] = []
    i = 0
    while i < len(s):
        c = s[i]
        if in_str:
            cur.append(c)
            if c == '"' and s[i - 1] != "\\":
                in_str = False
        elif c == '"':
            in_str = True
            cur.append(c)
        elif c in "([{":
            depth += 1
            cur.append(c)
        elif c in ")]}":
            depth -= 1
            cur.append(c)
        elif c == sep and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(c)
        i += 1
    if cur:
        parts.append("".join(cur).strip())
    return [p for p in parts if p]


def parse_shape(text: str) -> ShapeInfo:
    """Parse an HLO shape string: 'bf16[4,128]{1,0:T(8,128)}' or tuples."""
    text = text.strip()
    if text.startswith("("):
        # Tuple shape.
        inner = text[1:text.rindex(")")]
        elems = tuple(parse_shape(p) for p in _split_top_level(inner))
        return ShapeInfo(dtype="tuple", dims=(), elements=elems)
    m = re.match(r"([a-z0-9]+)\[([0-9,\s]*)\]", text)
    if not m:
        # Scalar without brackets, e.g. 'token[]' handled above; bare types:
        m2 = re.match(r"([a-z0-9]+)", text)
        return ShapeInfo(dtype=m2.group(1) if m2 else "f32", dims=())
    dtype = m.group(1)
    dims_txt = m.group(2).strip()
    dims = tuple(int(d) for d in dims_txt.split(",") if d.strip()) if dims_txt else ()
    return ShapeInfo(dtype=dtype, dims=dims)


def _take_shape_prefix(rest: str) -> Tuple[str, str]:
    """Split '<shape> <opcode>(...)...' into (shape_text, remainder)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, c in enumerate(rest):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    return rest[: i + 1], rest[i + 1:].strip()
        raise ValueError(f"unbalanced tuple shape in: {rest[:80]}")
    # array shape: dtype[dims]{layout}? then whitespace
    m = re.match(r"^([a-z0-9]+(?:\[[^\]]*\])?(?:\{[^}]*\})?)\s+(.*)$", rest)
    if not m:
        raise ValueError(f"cannot parse shape prefix from: {rest[:80]}")
    return m.group(1), m.group(2)


def _parse_operand_refs(operand_text: str) -> Tuple[str, ...]:
    refs: List[str] = []
    for part in _split_top_level(operand_text):
        # operand may be '%name' or 'f32[16]{0} %name'
        toks = part.split()
        name = None
        for tok in reversed(toks):
            if tok.startswith("%"):
                name = tok[1:]
                break
        if name is not None:
            refs.append(name)
    return tuple(refs)


_CALLED_COMP_KEYS = (
    "to_apply", "calls", "condition", "body", "true_computation",
    "false_computation", "branch_computations", "called_computations",
    "select", "scatter",
)


def _extract_comp_refs(value: str) -> List[str]:
    return [m.group(1) for m in re.finditer(r"%([\w.\-]+)", value)]


def _parse_metadata(value: str) -> Dict[str, str]:
    md: Dict[str, str] = {}
    for key in ("op_name", "source_file"):
        m = re.search(key + r'="((?:[^"\\]|\\.)*)"', value)
        if m:
            md[key] = m.group(1)
    m = re.search(r"source_line=(\d+)", value)
    if m:
        md["source_line"] = m.group(1)
    return md


def _replica_group_size(attr: str, total_devices: Optional[int]) -> int:
    """Parse replica_groups attr -> participants per group."""
    # Compact format: [num_groups,group_size]<=[...]
    m = re.match(r"\[(\d+),(\d+)\]<=", attr.strip())
    if m:
        return int(m.group(2))
    # Explicit format: {{0,1,2,3},{4,5,6,7}}
    m = re.match(r"\{\{([^}]*)\}", attr.strip())
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    if total_devices:
        return total_devices
    return 1


class HloParser:
    """Parse optimized HLO module text into the unified instruction model."""

    def __init__(self, hints: Optional[dict] = None):
        self.hints = hints or {}

    # -- public API ---------------------------------------------------------

    def parse(self, text: str) -> Module:
        module = Module(name=self._module_name(text), source="hlo")
        cur: Optional[Computation] = None
        for raw_line in text.splitlines():
            line = raw_line.rstrip()
            stripped = line.strip()
            if not stripped or stripped.startswith("//"):
                continue
            if stripped.startswith("HloModule"):
                continue
            if stripped == "}" or stripped == "})":
                cur = None
                continue
            header = _COMP_HEADER_RE.match(line) if stripped.endswith("{") else None
            if header and "=" not in stripped.split("(")[0]:
                name = header.group("name")
                cur = Computation(name=name)
                if header.group("entry"):
                    module.entry = name
                    cur.kind = "entry"
                module.add_computation(cur)
                continue
            if cur is None:
                continue
            instr = self._parse_instruction(stripped, cur.name)
            if instr is not None:
                cur.add(instr)
        if not module.entry and module.computations:
            module.entry = next(reversed(module.computations))
        self._finalize(module)
        return module

    # -- line-level parsing ---------------------------------------------------

    def _module_name(self, text: str) -> str:
        m = re.search(r"HloModule\s+([\w.\-]+)", text)
        return m.group(1) if m else "module"

    def _parse_instruction(self, line: str, comp_name: str) -> Optional[Instruction]:
        m = _INSTR_RE.match(line)
        if not m:
            return None
        name = m.group("name")
        try:
            shape_txt, remainder = _take_shape_prefix(m.group("rest"))
        except ValueError:
            return None
        shape = parse_shape(shape_txt)
        # opcode(...)
        om = re.match(r"^([\w\-]+)\(", remainder)
        if not om:
            return None
        opcode = om.group(1)
        # find matching close paren for the operand list
        start = om.end() - 1
        depth = 0
        end = start
        in_str = False
        for i in range(start, len(remainder)):
            c = remainder[i]
            if in_str:
                if c == '"' and remainder[i - 1] != "\\":
                    in_str = False
                continue
            if c == '"':
                in_str = True
            elif c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_text = remainder[start + 1:end]
        attr_text = remainder[end + 1:].lstrip(", ")

        attributes: Dict[str, str] = {}
        called: List[str] = []
        op_name = ""
        source_file = ""
        source_line = 0
        replica_groups = ""
        for part in _split_top_level(attr_text):
            if "=" not in part:
                attributes[part] = ""
                continue
            key, _, value = part.partition("=")
            key = key.strip()
            value = value.strip()
            attributes[key] = value
            if key in _CALLED_COMP_KEYS:
                called.extend(_extract_comp_refs(value))
            elif key == "metadata":
                md = _parse_metadata(value)
                op_name = md.get("op_name", "")
                source_file = md.get("source_file", "")
                source_line = int(md.get("source_line", 0))
            elif key == "replica_groups":
                replica_groups = value

        if opcode in _LITERAL_OPERAND_OPCODES:
            operands: Tuple[str, ...] = ()
            attributes["literal"] = operand_text
        else:
            operands = _parse_operand_refs(operand_text)

        op_class = classify_opcode(opcode)
        if opcode == "custom-call":
            target = attributes.get("custom_call_target", "")
            if any(k in target.lower() for k in ("dot", "gemm", "matmul", "conv")):
                op_class = OpClass.MATMUL

        instr = Instruction(
            name=name,
            opcode=opcode,
            op_class=op_class,
            shape=shape,
            operands=operands,
            computation=comp_name,
            index=0,
            attributes=attributes,
            op_name=op_name,
            source_file=source_file,
            source_line=source_line,
            replica_groups=replica_groups,
            called_computations=tuple(called),
            is_root=bool(m.group("root")),
        )
        return instr

    # -- module finalization --------------------------------------------------

    def _finalize(self, module: Module) -> None:
        self._mark_computation_kinds(module)
        self._annotate_costs(module)
        self._annotate_sync(module)
        self._annotate_trip_counts(module)
        self._fold_fusion_costs(module)
        self._zero_inner_bytes(module)
        if self.hints.get("virtual_fusion", True):
            from .fusion_model import apply_virtual_fusion
            apply_virtual_fusion(module)

    def _mark_computation_kinds(self, module: Module) -> None:
        for comp in module.computations.values():
            for instr in comp.instructions:
                for idx, callee in enumerate(instr.called_computations):
                    target = module.computations.get(callee)
                    if target is None:
                        continue
                    target.parent_op = instr.qualified_name
                    if instr.opcode == "fusion":
                        target.kind = "fusion"
                    elif instr.opcode == "while":
                        # condition first, body second by attribute order
                        cond = _extract_comp_refs(
                            instr.attributes.get("condition", ""))
                        target.kind = "loop_cond" if callee in cond else "loop_body"
                    elif instr.opcode == "conditional":
                        target.kind = "branch"
                    elif instr.opcode in ("reduce", "reduce-window", "sort",
                                          "scatter", "select-and-scatter",
                                          "all-reduce", "all-reduce-start",
                                          "reduce-scatter"):
                        target.kind = "reduce"
                    elif target.kind == "plain":
                        target.kind = "called"

    # cost annotation ---------------------------------------------------------

    def _annotate_costs(self, module: Module) -> None:
        total_devices = self.hints.get("total_devices")
        for comp in module.computations.values():
            for instr in comp.instructions:
                self._cost_one(module, comp, instr, total_devices)

    def _cost_one(self, module: Module, comp: Computation, instr: Instruction,
                  total_devices: Optional[int]) -> None:
        out_elems = instr.shape.num_elements
        opc = instr.opcode
        cls = instr.op_class

        if opc == "dot":
            lhs = comp.get(instr.operands[0]) if instr.operands else None
            k = 1
            if lhs is not None:
                cdims = re.findall(r"\d+", instr.attributes.get(
                    "lhs_contracting_dims", ""))
                for d in cdims:
                    di = int(d)
                    if di < len(lhs.shape.dims):
                        k *= lhs.shape.dims[di]
            instr.flops = 2.0 * out_elems * k
        elif opc == "convolution":
            # approximation: 2 * out_elems * kernel_elems
            rhs = comp.get(instr.operands[1]) if len(instr.operands) > 1 else None
            kern = rhs.shape.num_elements if rhs is not None else 1
            instr.flops = 2.0 * out_elems * kern
        elif cls is OpClass.REDUCE:
            in_elems = 0
            for op_name_ in instr.operands:
                src = comp.get(op_name_)
                if src is not None:
                    in_elems += src.shape.num_elements
            instr.flops = float(max(in_elems, out_elems))
        elif cls is OpClass.COMPUTE:
            per_elem = 8.0 if opc in _TRANSCENDENTAL else 1.0
            instr.flops = per_elem * out_elems

        # HBM bytes: operand reads + output write (per-device local shapes).
        bytes_read = 0.0
        for op_name_ in instr.operands:
            src = comp.get(op_name_)
            if src is not None:
                bytes_read += src.shape.byte_size
        instr.bytes_read = bytes_read
        instr.bytes_written = float(instr.shape.byte_size)
        if cls in (OpClass.PARAMETER, OpClass.CONSTANT):
            instr.bytes_read = float(instr.shape.byte_size)
            instr.bytes_written = 0.0
        if cls in (OpClass.TUPLE, OpClass.CONTROL):
            # Glue and region ops move no data themselves; their bodies (or
            # callee accounting) carry the traffic.
            instr.bytes_read = 0.0
            instr.bytes_written = 0.0
        # Sliced access touches only the slice, not the whole operand — a
        # one-token dynamic-update-slice into a 32k KV cache costs one
        # token's bytes (TPU updates in place), not the cache.
        if opc in ("slice", "dynamic-slice"):
            instr.bytes_read = float(instr.shape.byte_size)
        elif opc == "gather":
            idx_bytes = 0.0
            rows = 1
            if len(instr.operands) > 1:
                src = comp.get(instr.operands[1])
                if src is not None:
                    idx_bytes = float(src.shape.byte_size)
                    rows = max(1, src.shape.num_elements)
            useful = float(instr.shape.byte_size)
            # HBM moves >=256B granules: small gathered rows pay the full
            # granule (the uncoalesced-access analogue the paper's
            # efficiency factor penalizes).
            per_row = useful / rows
            if per_row < 256.0:
                # cap at 8x: real gathers coalesce partially
                useful = min(rows * 256.0, 8.0 * useful)
            instr.bytes_read = useful + idx_bytes
        elif opc in ("dynamic-update-slice", "scatter"):
            upd_bytes = 0.0
            for op_name_ in instr.operands[1:]:
                src = comp.get(op_name_)
                if src is not None:
                    upd_bytes += float(src.shape.byte_size)
            instr.bytes_read = upd_bytes
            instr.bytes_written = upd_bytes

        # Collective bytes over ICI, per participating chip.
        if cls in (OpClass.COLLECTIVE, OpClass.SYNC_SET) and \
                opc not in ("copy-start", "send", "async-start"):
            n = _replica_group_size(instr.replica_groups, total_devices)
            base = opc.replace("-start", "")
            in_bytes = bytes_read
            out_bytes = float(instr.shape.byte_size)
            if n <= 1:
                instr.comm_bytes = 0.0
            elif base == "all-reduce":
                instr.comm_bytes = 2.0 * in_bytes * (n - 1) / n
            elif base == "all-gather":
                instr.comm_bytes = out_bytes * (n - 1) / n
            elif base == "reduce-scatter":
                instr.comm_bytes = in_bytes * (n - 1) / n
            elif base == "all-to-all":
                instr.comm_bytes = in_bytes * (n - 1) / n
            elif base in ("collective-permute", "collective-broadcast"):
                instr.comm_bytes = in_bytes
            else:
                instr.comm_bytes = in_bytes
        if opc in ("send", "recv"):
            instr.comm_bytes = float(instr.shape.byte_size)

    def _annotate_sync(self, module: Module) -> None:
        """Attach §III-E synchronization semantics.

        HLO async pairs are the NVIDIA-barrier analogue: the ``*-start`` op
        "sets a barrier" named by itself; the matching ``*-done`` op "waits"
        on it.  Token-typed values (after-all / optimization-barrier and any
        op producing/consuming ``token[]``) are the Intel-SWSB analogue.

        ``frontend_attributes={sync_tag="..."}`` overrides the identifier a
        start op sets (and, transitively, what its waiters wait on): this is
        the textual carrier for the advisor's ``CoalesceSyncTags`` rewrite —
        several starts sharing one tag re-arm one physical sync instance
        instead of allocating one each.  Without the attribute the identifier
        is the op's own name, exactly as before.
        """
        for comp in module.computations.values():
            for instr in comp.instructions:
                if instr.op_class is OpClass.SYNC_SET:
                    instr.sync = SyncInfo(kind=SyncKind.BARRIER,
                                          sets=(self._sync_tag(instr),))
                elif instr.op_class is OpClass.SYNC_WAIT:
                    instr.sync = SyncInfo(
                        kind=SyncKind.BARRIER,
                        waits=tuple(self._effective_tag(comp, op)
                                    for op in instr.operands))
                elif instr.shape.dtype == "token" or instr.opcode == "after-all":
                    instr.sync = SyncInfo(
                        kind=SyncKind.TOKEN,
                        sets=(self._sync_tag(instr),),
                        waits=tuple(self._effective_tag(comp, op)
                                    for op in instr.operands))

    @staticmethod
    def _sync_tag(instr: Instruction) -> str:
        m = _SYNC_TAG_RE.search(instr.attributes.get("frontend_attributes",
                                                     ""))
        return m.group(1) if m else instr.name

    def _effective_tag(self, comp: Computation, operand: str) -> str:
        """The sync identifier an operand reference waits on: the operand
        op's sync_tag when declared, its name otherwise (unknown operands
        keep their name, matching the pre-sync_tag behavior)."""
        src = comp.get(operand)
        return operand if src is None else self._sync_tag(src)

    def _annotate_trip_counts(self, module: Module) -> None:
        hinted = dict(self.hints.get("while_trip_counts", {}))
        for comp in module.computations.values():
            for instr in comp.instructions:
                if instr.opcode != "while":
                    continue
                if instr.name in hinted:
                    instr.trip_count = int(hinted[instr.name])
                    continue
                cond_names = _extract_comp_refs(
                    instr.attributes.get("condition", ""))
                instr.trip_count = max(
                    1, self._trip_count_from_cond(module, cond_names))

    def _trip_count_from_cond(self, module: Module,
                              cond_names: List[str]) -> int:
        best = 1
        for cname in cond_names:
            comp = module.computations.get(cname)
            if comp is None:
                continue
            for instr in comp.instructions:
                if instr.opcode != "constant":
                    continue
                lit = instr.attributes.get("literal", "")
                m = re.search(r"-?\d+", lit)
                if m and instr.shape.dtype.startswith(("s", "u")):
                    best = max(best, int(m.group(0)))
        return best

    def _fold_fusion_costs(self, module: Module) -> None:
        """fusion-node flops = sum of inner flops (inner ops live in VMEM)."""
        memo: Dict[str, float] = {}

        def comp_flops(cname: str, stack: frozenset) -> float:
            if cname in memo:
                return memo[cname]
            if cname in stack or cname not in module.computations:
                return 0.0
            total = 0.0
            for instr in module.computations[cname].instructions:
                total += instr.flops
                for callee in instr.called_computations:
                    total += instr.trip_count * comp_flops(
                        callee, stack | {cname})
            memo[cname] = total
            return total

        for comp in module.computations.values():
            for instr in comp.instructions:
                if instr.opcode == "fusion" and instr.called_computations:
                    inner = sum(comp_flops(c, frozenset())
                                for c in instr.called_computations)
                    instr.flops += inner

    def _zero_inner_bytes(self, module: Module) -> None:
        """Instructions inside fusion/reduce bodies are VMEM-resident."""
        for comp in module.computations.values():
            if comp.kind in ("fusion", "reduce"):
                for instr in comp.instructions:
                    instr.raw_bytes_read = instr.bytes_read
                    instr.bytes_read = 0.0
                    instr.bytes_written = 0.0


def parse_hlo(text: str, hints: Optional[dict] = None) -> Module:
    return HloParser(hints=hints).parse(text)
