"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init — the
dry-run must set XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (smoke tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
