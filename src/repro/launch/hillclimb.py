import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: lower one cell under a sequence of optimization
variants, record roofline terms + LEO's diagnosis per step.

Each variant is (name, model flags, TrainOptions overrides).  Results land
in experiments/perf/<arch>__<shape>__<variant>.json; EXPERIMENTS.md §Perf is
written from these artifacts.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen2
"""
import argparse
import json
import time

import jax


CELLS = {
    "qwen2": {
        "arch": "qwen2-0.5b", "shape": "train_4k",
        "variants": [
            ("baseline", {}, {}),
            ("flash_attention", {"attention_impl": "pallas_fused"}, {}),
            ("flash+microbatch1",
             {"attention_impl": "pallas_fused"}, {"microbatch": 1}),
            ("flash+mb1+remat_none",
             {"attention_impl": "pallas_fused"},
             {"microbatch": 1, "remat": "none"}),
            ("flash+mb1+bf16grads",
             {"attention_impl": "pallas_fused"},
             {"microbatch": 1, "grad_dtype": "bf16"}),
        ],
    },
    "hymba": {
        "arch": "hymba-1.5b", "shape": "train_4k",
        "variants": [
            ("baseline", {}, {}),
            ("ssm_fused", {"ssm_fused": True}, {}),
            ("ssm_fused+flash",
             {"ssm_fused": True, "attention_impl": "pallas_fused"}, {}),
            ("ssm+flash+mb2",
             {"ssm_fused": True, "attention_impl": "pallas_fused"},
             {"microbatch": 2}),
            ("ssm_pallas+flash",
             {"ssm_fused": True, "ssm_pallas": True,
              "attention_impl": "pallas_fused"}, {}),
        ],
    },
    "dsv2": {
        "arch": "deepseek-v2-236b", "shape": "train_4k",
        "variants": [
            ("baseline", {}, {}),
            ("ep_shardmap", {"moe_impl": "ep_shardmap"}, {}),
            ("ep+flash",
             {"moe_impl": "ep_shardmap",
              "attention_impl": "pallas_fused"}, {}),
            ("ep+flash+remat_none",
             {"moe_impl": "ep_shardmap",
              "attention_impl": "pallas_fused"}, {"remat": "none"}),
            ("ep+flash+save_moe",
             {"moe_impl": "ep_shardmap",
              "attention_impl": "pallas_fused"},
             {"remat": "group_save_moe"}),
        ],
    },
}


def run_variant(arch, shape_name, name, model_flags, opt_overrides,
                mesh_kind, outdir, hw_name="tpu_v5e", analyze=True,
                force=False):
    from ..configs import get_config, get_shape, model_flops
    from ..core import get_backend
    from ..core.roofline import compute_roofline
    from ..models.flags import flags as flags_ctx
    from ..runtime.steps import TrainOptions, default_microbatch
    from .dryrun import get_service, lower_cell
    from .mesh import make_production_mesh

    label = f"{arch}__{shape_name}__{name}"
    path = os.path.join(outdir, label + ".json")
    if os.path.exists(path) and not force:
        return json.load(open(path))

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(len(mesh.devices.flat))
    dp = chips // mesh.shape["model"]
    defaults = dict(microbatch=default_microbatch(
        cfg, shape.global_batch, shape.seq_len, dp))
    defaults.update(opt_overrides)
    opts = TrainOptions(**defaults)

    with flags_ctx(**model_flags):
        lowered, compiled, secs = lower_cell(cfg, shape, mesh, opts=opts)
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    service = get_service(outdir)
    hints = {"total_devices": chips}
    module = service.parse(hlo, hints=hints)
    hw = get_backend(hw_name).hw
    rl = compute_roofline(module, hw, chips=chips, label=label,
                          model_flops=model_flops(cfg, shape),
                          cost_analysis=compiled.cost_analysis(),
                          memory_analysis=mem)
    result = {"label": label, "variant": name, "flags": model_flags,
              "options": opt_overrides, "compile_seconds": secs,
              "roofline": rl.to_dict()}
    if analyze:
        rep = service.diagnose(hlo, backend=hw_name, hints=hints).to_dict()
        result["leo"] = {
            "top_stalls": rep["top_stalls"][:3],
            "root_causes": rep["root_causes"][:5],
            "self_blame": rep["self_blame"][:3],
            "recommendations": rep["recommendations"][:4],
            "estimated_step_seconds": rep["estimated_step_seconds"],
        }
    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[{name}] {rl.summary_row()}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--outdir", default="experiments/perf")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    spec = CELLS[args.cell]
    for name, model_flags, opt_overrides in spec["variants"]:
        run_variant(spec["arch"], spec["shape"], name, model_flags,
                    opt_overrides, args.mesh, args.outdir, force=args.force)


if __name__ == "__main__":
    main()
