"""§Perf hillclimb driver: lower one cell under a sequence of optimization
variants, record roofline terms + LEO's diagnosis per step — plus a
model-only **what-if search** mode that climbs the advisor's mutation
space without lowering anything (no jax import on that path).

Each variant is (name, model flags, TrainOptions overrides).  Results land
in experiments/perf/<arch>__<shape>__<variant>.json; EXPERIMENTS.md §Perf is
written from these artifacts.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen2
  PYTHONPATH=src python -m repro.launch.hillclimb --whatif \\
      --backend nvidia_gh200 --mode guided --budget 12 --seed 0
"""
import argparse
import json
import os
import random
import time


CELLS = {
    "qwen2": {
        "arch": "qwen2-0.5b", "shape": "train_4k",
        "variants": [
            ("baseline", {}, {}),
            ("flash_attention", {"attention_impl": "pallas_fused"}, {}),
            ("flash+microbatch1",
             {"attention_impl": "pallas_fused"}, {"microbatch": 1}),
            ("flash+mb1+remat_none",
             {"attention_impl": "pallas_fused"},
             {"microbatch": 1, "remat": "none"}),
            ("flash+mb1+bf16grads",
             {"attention_impl": "pallas_fused"},
             {"microbatch": 1, "grad_dtype": "bf16"}),
        ],
    },
    "hymba": {
        "arch": "hymba-1.5b", "shape": "train_4k",
        "variants": [
            ("baseline", {}, {}),
            ("ssm_fused", {"ssm_fused": True}, {}),
            ("ssm_fused+flash",
             {"ssm_fused": True, "attention_impl": "pallas_fused"}, {}),
            ("ssm+flash+mb2",
             {"ssm_fused": True, "attention_impl": "pallas_fused"},
             {"microbatch": 2}),
            ("ssm_pallas+flash",
             {"ssm_fused": True, "ssm_pallas": True,
              "attention_impl": "pallas_fused"}, {}),
        ],
    },
    "dsv2": {
        "arch": "deepseek-v2-236b", "shape": "train_4k",
        "variants": [
            ("baseline", {}, {}),
            ("ep_shardmap", {"moe_impl": "ep_shardmap"}, {}),
            ("ep+flash",
             {"moe_impl": "ep_shardmap",
              "attention_impl": "pallas_fused"}, {}),
            ("ep+flash+remat_none",
             {"moe_impl": "ep_shardmap",
              "attention_impl": "pallas_fused"}, {"remat": "none"}),
            ("ep+flash+save_moe",
             {"moe_impl": "ep_shardmap",
              "attention_impl": "pallas_fused"},
             {"remat": "group_save_moe"}),
        ],
    },
}


def run_variant(arch, shape_name, name, model_flags, opt_overrides,
                mesh_kind, outdir, hw_name="tpu_v5e", analyze=True,
                force=False):
    # jax and the host-device XLA flag are only needed when actually
    # lowering; importing here keeps the what-if search path light
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax  # noqa: F401

    from ..configs import get_config, get_shape, model_flops
    from ..core import get_backend
    from ..core.roofline import compute_roofline
    from ..models.flags import flags as flags_ctx
    from ..runtime.steps import TrainOptions, default_microbatch
    from .dryrun import get_service, lower_cell
    from .mesh import make_production_mesh

    label = f"{arch}__{shape_name}__{name}"
    path = os.path.join(outdir, label + ".json")
    if os.path.exists(path) and not force:
        return json.load(open(path))

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(len(mesh.devices.flat))
    dp = chips // mesh.shape["model"]
    defaults = dict(microbatch=default_microbatch(
        cfg, shape.global_batch, shape.seq_len, dp))
    defaults.update(opt_overrides)
    opts = TrainOptions(**defaults)

    with flags_ctx(**model_flags):
        lowered, compiled, secs = lower_cell(cfg, shape, mesh, opts=opts)
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    service = get_service(outdir)
    hints = {"total_devices": chips}
    module = service.parse(hlo, hints=hints)
    hw = get_backend(hw_name).hw
    rl = compute_roofline(module, hw, chips=chips, label=label,
                          model_flops=model_flops(cfg, shape),
                          cost_analysis=compiled.cost_analysis(),
                          memory_analysis=mem)
    result = {"label": label, "variant": name, "flags": model_flags,
              "options": opt_overrides, "compile_seconds": secs,
              "roofline": rl.to_dict()}
    if analyze:
        rep = service.diagnose(hlo, backend=hw_name, hints=hints).to_dict()
        result["leo"] = {
            "top_stalls": rep["top_stalls"][:3],
            "root_causes": rep["root_causes"][:5],
            "self_blame": rep["self_blame"][:3],
            "recommendations": rep["recommendations"][:4],
            "estimated_step_seconds": rep["estimated_step_seconds"],
        }
    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[{name}] {rl.summary_row()}")
    return result


# ---------------------------------------------------------------------------
# What-if search: hillclimb over the advisor's mutation space, entirely in
# the model (no lowering, no jax).  The §VII "LEO-guided optimization" loop
# in miniature — `guided` mode replays the advisor's rule-matched candidates
# first; `blind` shuffles the full space under an explicit --seed, so the
# guided-vs-blind comparison is reproducible run to run.
# ---------------------------------------------------------------------------

def mutation_space(backend):
    """Deterministic enumeration of every knob a Mutation can turn on
    this backend, at a few settings each — the blind search's universe."""
    from ..advisor import (
        CoalesceSyncTags,
        PipelineAsyncChain,
        ResizePool,
        ScaleLatency,
        SetIssue,
        SetOccupancy,
        TreeReduceChain,
    )
    from ..core.hwmodel import ISSUE_POLICIES

    space = []
    for p in backend.sync.pools:
        for cap in sorted({p.capacity * 2, p.capacity + 4,
                           max(1, p.capacity // 2)} - {p.capacity}):
            space.append(ResizePool(pool=p.name, capacity=cap))
    for group in (2, 4, 8, 16):
        space.append(CoalesceSyncTags(group=group))
    for window in (2, 4, 8):
        space.append(PipelineAsyncChain(window=window))
    space.append(TreeReduceChain())
    iss = backend.issue
    for queues in sorted({max(1, iss.queues // 2), iss.queues * 2}):
        space.append(SetIssue(queues=queues))
    space.append(SetIssue(width=iss.width * 2))
    for policy in ISSUE_POLICIES:
        if policy != iss.policy:
            space.append(SetIssue(policy=policy))
    space.append(ScaleLatency(hw_field="hbm_bw", factor=2.0))
    space.append(ScaleLatency(hw_field="dma_setup_cycles", factor=0.5))
    native = backend.native_occupancy
    if native.multi_wave:
        for waves in sorted({native.waves, max(2, native.waves // 2)}):
            space.append(SetOccupancy(waves=waves))
    return space


def whatif_search(module, backend, *, mode="blind", budget=12, seed=0,
                  target_speedup=None):
    """Search the mutation space for the best modeled speedup.

    ``blind`` replays a seeded-shuffle order over :func:`mutation_space`;
    ``guided`` replays in advisor order — the top candidate of every
    *matched* rule first, then each unmatched rule's top pick as a
    speculative tier, then the matched rules' remaining candidates, then
    the same shuffled space (rule matching prices nothing — ordering is
    free).  Both stop after ``budget`` replays, or as soon as
    ``target_speedup`` is reached — so "how many evaluations did the
    advisor save?" is a direct read of the two ``evaluations`` counts.
    """
    from ..advisor import RULES, Evidence, WhatIfEngine, match_rules

    engine = WhatIfEngine(module, backend)
    baseline = engine.baseline()
    candidates = mutation_space(backend)
    rng = random.Random(seed)
    rng.shuffle(candidates)
    if mode == "guided":
        evidence = Evidence(backend=backend, profile=baseline)
        matched = {r.name for r in match_rules(evidence)}
        tiers = ([], [], [])   # matched picks | speculative picks | rest
        for rule in RULES:
            cands = rule.candidates(evidence)
            if not cands:
                continue
            if rule.name in matched:
                tiers[0].append(cands[0])
                tiers[2].extend(cands[1:])
            else:
                tiers[1].append(cands[0])
        advised = [m for tier in tiers for m in tier]
        seen = {json.dumps(m.to_dict(), sort_keys=True) for m in advised}
        candidates = advised + [
            m for m in candidates
            if json.dumps(m.to_dict(), sort_keys=True) not in seen]
    elif mode != "blind":
        raise ValueError(f"mode must be 'blind' or 'guided', got {mode!r}")

    best = None
    best_at = 0
    evaluations = 0
    history = []
    for mutation in candidates[:budget]:
        res = engine.replay(mutation)
        evaluations += 1
        history.append({"evaluation": evaluations,
                        "mutation": mutation.to_dict(),
                        "modeled_speedup": res.modeled_speedup})
        if best is None or res.modeled_speedup > best.modeled_speedup:
            best = res
            best_at = evaluations
        if target_speedup is not None \
                and best.modeled_speedup >= target_speedup:
            break
    return {
        "mode": mode,
        "seed": seed,
        "budget": budget,
        "backend": backend.name,
        "baseline_makespan_cycles": baseline.makespan_cycles,
        "evaluations": evaluations,
        "evaluations_to_best": best_at,
        "best": best.to_dict() if best is not None else None,
        "best_speedup": best.modeled_speedup if best is not None else 1.0,
        "history": history,
    }


def run_whatif(backend_name, *, mode="both", budget=12, seed=0,
               n_copies=48, outdir=None, hlo_text=None):
    """CLI entry for the model-only search; returns per-mode results."""
    from ..core import parse_hlo, resolve_backend
    from .analysis_server import copy_storm_hlo

    backend = resolve_backend(backend_name)
    module = parse_hlo(hlo_text if hlo_text is not None
                       else copy_storm_hlo(n_copies))
    modes = ("blind", "guided") if mode == "both" else (mode,)
    out = {}
    for m in modes:
        # guided chases the blind best, so the evaluation counts compare
        target = out.get("blind", {}).get("best_speedup")
        t0 = time.monotonic()
        res = whatif_search(module, backend, mode=m, budget=budget,
                            seed=seed, target_speedup=target)
        res["search_seconds"] = time.monotonic() - t0
        out[m] = res
        best = res["best"] or {}
        print(f"[whatif:{m}] {backend.name} best "
              f"{res['best_speedup']:.3f}x in {res['evaluations']} evals "
              f"({(best.get('mutation') or {}).get('kind', '-')})")
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        path = os.path.join(outdir, f"whatif__{backend.name}__s{seed}.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[whatif] wrote {path}")
    return out


def run_rewrite(backend_name, *, top_k=2, n_copies=48, outdir=None,
                hlo_text=None):
    """CLI entry for the closed loop: lower the advisor's top advice to
    equivalence-checked HLO rewrites via the real text path (emit ->
    re-parse -> full re-analysis) and report predicted vs realized."""
    from ..core import resolve_backend
    from ..core.session import LeoSession
    from ..rewrite import RewriteLoop
    from .analysis_server import copy_storm_hlo

    backend = resolve_backend(backend_name)
    text = hlo_text if hlo_text is not None else copy_storm_hlo(n_copies)
    session = LeoSession()
    t0 = time.monotonic()
    report = RewriteLoop(top_k=top_k).run(text, backend, session=session)
    seconds = time.monotonic() - t0
    out = report.to_dict()
    out["loop_seconds"] = seconds
    for o in report.outcomes:
        print(f"[rewrite:{backend.name}] {o.rule} ({o.source}): "
              f"{o.mutation.get('kind')} predicted "
              f"{o.predicted_speedup:.3f}x -> realized "
              f"{o.realized_speedup:.3f}x "
              f"({o.realized_fraction:.0%} of predicted)")
    for s in report.skipped:
        print(f"[rewrite:{backend.name}] skipped {s['rule']}: "
              f"{s['refusal']['code']}")
    best = report.best
    print(f"[rewrite:{backend.name}] best "
          f"{best.realized_speedup:.3f}x realized"
          if best is not None else
          f"[rewrite:{backend.name}] no applicable rewrite")
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        path = os.path.join(outdir, f"rewrite__{backend.name}.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[rewrite] wrote {path}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(CELLS))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--outdir", default="experiments/perf")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--whatif", action="store_true",
                    help="run the model-only mutation search instead of "
                         "lowering a cell")
    ap.add_argument("--rewrite", action="store_true",
                    help="lower the advisor's top advice to equivalence-"
                         "checked HLO rewrites and measure realized vs "
                         "predicted speedup via the real text path")
    ap.add_argument("--top-k", type=int, default=2,
                    help="advice items the --rewrite loop lowers")
    ap.add_argument("--backend", default="nvidia_gh200")
    ap.add_argument("--mode", default="both",
                    choices=("blind", "guided", "both"))
    ap.add_argument("--budget", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0,
                    help="shuffle seed for the blind search order; "
                         "explicit so guided-vs-blind comparisons "
                         "reproduce exactly")
    ap.add_argument("--copies", type=int, default=48,
                    help="copy-storm width for the --whatif workload")
    args = ap.parse_args()

    if args.rewrite:
        run_rewrite(args.backend, top_k=args.top_k, n_copies=args.copies,
                    outdir=args.outdir)
        return
    if args.whatif:
        run_whatif(args.backend, mode=args.mode, budget=args.budget,
                   seed=args.seed, n_copies=args.copies,
                   outdir=args.outdir)
        return
    if args.cell is None:
        ap.error("--cell is required unless --whatif or --rewrite is given")
    spec = CELLS[args.cell]
    for name, model_flags, opt_overrides in spec["variants"]:
        run_variant(spec["arch"], spec["shape"], name, model_flags,
                    opt_overrides, args.mesh, args.outdir, force=args.force)


if __name__ == "__main__":
    main()
