import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the right step (train_step / prefill_step / serve_step) with
     in/out shardings from `repro.parallel.sharding`,
  3. compiles, prints `memory_analysis()` (proves it fits) and
     `cost_analysis()` (FLOPs/bytes for the roofline),
  4. derives the three roofline terms (compute / memory / collective) from
     the compiled HLO via LEO's parser, and
  5. optionally runs the full LEO root-cause analysis (--analyze).

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json (plus the
HLO text with --save-hlo) and are consumed by benchmarks and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh both --analyze
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
"""
import argparse
import gzip
import json
import time
import traceback
from functools import partial

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(cfg, shape, mesh, opts=None):
    """Lower + compile one (arch, shape, mesh) cell. Returns (lowered,
    compiled, seconds)."""
    from ..parallel.sharding import ShardingRules
    from ..runtime.steps import (
        TrainOptions,
        default_microbatch,
        make_prefill_step,
        make_serve_step,
        make_train_step,
    )
    from . import specs as S

    from ..parallel.context import set_current_mesh
    set_current_mesh(mesh)
    rules = ShardingRules(mesh, cfg)
    if opts is None:
        import numpy as np
        dp = int(np.prod([mesh.shape[a] for a in rules.dp_axes]))
        opts = TrainOptions(microbatch=default_microbatch(
            cfg, shape.global_batch, shape.seq_len, dp))
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            state = S.abstract_train_state(cfg)
            batch = S.batch_specs(cfg, shape)
            pspecs = rules.param_specs(state["params"])
            ospecs = rules.opt_specs(state["opt"], state["params"])
            state_specs = {"params": pspecs, "opt": ospecs, "step": P()}
            bspecs = rules.batch_specs(cfg, shape)
            step = make_train_step(cfg, options=opts)
            lowered = jax.jit(
                step,
                donate_argnums=(0,),  # train state updates in place
                in_shardings=(_sharding_tree(mesh, state_specs),
                              _sharding_tree(mesh, bspecs)),
                out_shardings=(_sharding_tree(mesh, state_specs),
                               None),
            ).lower(state, batch)
        elif shape.kind == "prefill":
            params = S.abstract_params(cfg)
            batch = S.batch_specs(cfg, shape)
            pspecs = rules.param_specs(params)
            bspecs = rules.batch_specs(cfg, shape)
            step = make_prefill_step(cfg, chunk=min(512, shape.seq_len))
            lowered = jax.jit(
                step,
                in_shardings=(_sharding_tree(mesh, pspecs),
                              _sharding_tree(mesh, bspecs)),
                out_shardings=NamedSharding(mesh, rules.logits_spec(shape)),
            ).lower(params, batch)
        else:  # decode
            params = S.abstract_params(cfg)
            dstate = S.abstract_decode_state(cfg, shape)
            pspecs = rules.param_specs(params)
            sspecs = rules.decode_state_specs(dstate, shape)
            bspecs = rules.batch_specs(cfg, shape)
            step = make_serve_step(cfg)
            tok_sharding = NamedSharding(mesh, bspecs["token"])
            lowered = jax.jit(
                step,
                donate_argnums=(1,),  # KV cache / state updates in place
                in_shardings=(_sharding_tree(mesh, pspecs),
                              _sharding_tree(mesh, sspecs),
                              tok_sharding, NamedSharding(mesh, P())),
                out_shardings=(tok_sharding, None,
                               _sharding_tree(mesh, sspecs)),
            ).lower(params, dstate, S.batch_specs(cfg, shape)["token"],
                    S.batch_specs(cfg, shape)["pos"])
        compiled = lowered.compile()
    return lowered, compiled, time.time() - t0


def _parse_flags(spec: str) -> dict:
    out = {}
    for part in (spec or "").split(","):
        if not part.strip():
            continue
        k, _, v = part.partition("=")
        v = v.strip()
        if v.lower() in ("true", "false"):
            val = v.lower() == "true"
        else:
            try:
                val = int(v)
            except ValueError:
                val = v
        out[k.strip()] = val
    return out


_SERVICES = {}
_METRICS = None


def get_metrics():
    """Process-wide MetricsRegistry shared by every per-outdir service,
    so a sweep's `--metrics-out` dump covers all cells."""
    global _METRICS
    if _METRICS is None:
        from ..serve.metrics import MetricsRegistry
        _METRICS = MetricsRegistry()
    return _METRICS


def get_service(outdir: str):
    """One disk-backed LeoService per artifact dir: every cell in this
    process shares the parse/graph/analysis caches, and a *second process*
    re-running a cell against the warm `<outdir>/.leo_cache` performs zero
    HLO parses (modules and diagnoses reload from the content-addressed
    disk tier).  The tier is bounded — 512 MiB cap, 14-day idle TTL — so a
    long-lived sweep directory cannot grow without bound."""
    from ..core import LeoService
    svc = _SERVICES.get(outdir)
    if svc is None:
        svc = LeoService(cache_dir=os.path.join(outdir, ".leo_cache"),
                         disk_cache_max_bytes=512 * 2**20,
                         disk_cache_ttl_seconds=14 * 24 * 3600.0,
                         metrics=get_metrics())
        _SERVICES[outdir] = svc
    return svc


def run_cell(arch: str, shape_name: str, mesh_kind: str, outdir: str,
             analyze: bool = False, save_hlo: bool = False,
             hw_name: str = "tpu_v5e", force: bool = False,
             model_flags: dict = None) -> dict:
    from ..configs import get_config, get_shape, model_flops, shapes_for
    from ..core import get_backend
    from ..core.roofline import compute_roofline
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    label = f"{arch}__{shape_name}__{mesh_kind}"
    path = os.path.join(outdir, label + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    if shape.name == "long_500k" and not cfg.supports_long_context:
        result = {"label": label, "status": "skipped",
                  "reason": "full quadratic attention at 524k decode; "
                            "skip per DESIGN.md long-context applicability"}
        os.makedirs(outdir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(len(mesh.devices.flat))
    try:
        from ..models.flags import flags as flags_ctx
        with flags_ctx(**(model_flags or {})):
            lowered, compiled, secs = lower_cell(cfg, shape, mesh)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        service = get_service(outdir)
        hints = {"total_devices": chips}
        module = service.parse(hlo, hints=hints)
        hw = get_backend(hw_name).hw
        rl = compute_roofline(
            module, hw, chips=chips, label=label,
            model_flops=model_flops(cfg, shape),
            cost_analysis=cost, memory_analysis=mem)
        result = {"label": label, "status": "ok", "chips": chips,
                  "compile_seconds": secs, "roofline": rl.to_dict()}
        if analyze:
            diag = service.diagnose(hlo, backend=hw_name, hints=hints)
            result["leo"] = diag.to_dict()
        if save_hlo:
            with gzip.open(os.path.join(outdir, label + ".hlo.gz"),
                           "wt") as f:
                f.write(hlo)
        print(f"[ok] {label}: compile={secs:.1f}s  {rl.summary_row()}")
        print(f"     memory: {mem}")
    except Exception as e:  # noqa: BLE001 - report failures as cell results
        result = {"label": label, "status": "error",
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
        print(f"[FAIL] {label}: {type(e).__name__}: {e}")

    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def main() -> None:
    from ..configs import ALL_ARCHS, shapes_for

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--analyze", action="store_true",
                    help="run LEO root-cause analysis per cell")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--hw", default="tpu_v5e")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--flags", default="",
                    help="model flags, e.g. attention_impl=pallas_fused,"
                         "ssm_fused=true,ssm_pallas=true,"
                         "moe_impl=ep_shardmap")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the analysis-cache/latency metrics "
                         "(Prometheus text format) to PATH after the sweep")
    args = ap.parse_args()
    model_flags = _parse_flags(args.flags)

    archs = [c.name for c in ALL_ARCHS] if args.arch == "all" \
        else args.arch.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        from ..configs import get_config
        cfg = get_config(arch)
        shape_names = [s.name for s in shapes_for(cfg)] + (
            ["long_500k"] if not cfg.supports_long_context else [])
        if args.shape != "all":
            shape_names = args.shape.split(",")
        for shape_name in shape_names:
            for mesh_kind in meshes:
                r = run_cell(arch, shape_name, mesh_kind, args.outdir,
                             analyze=args.analyze, save_hlo=args.save_hlo,
                             hw_name=args.hw, force=args.force,
                             model_flags=model_flags)
                if r.get("status") == "error":
                    failures += 1
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(get_metrics().render())
        print(f"wrote metrics to {args.metrics_out}")
    print(f"\ndry-run complete; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
