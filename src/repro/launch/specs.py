"""Abstract input specs (ShapeDtypeStruct stand-ins) for every model input.

No device allocation ever happens here: params and decode state come from
`jax.eval_shape` over the real initializers, batches are synthesized
directly.  The same specs drive the multi-pod dry-run and the roofline
benchmarks.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models import init_decode_state, init_params
from ..runtime.steps import init_train_state

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {"labels": SDS((b, s), jnp.int32)}
        if cfg.frontend != "none":
            specs["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
        else:
            specs["tokens"] = SDS((b, s), jnp.int32)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"token": SDS((b,), jnp.int32), "pos": SDS((), jnp.int32)}


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def abstract_train_state(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg))


def abstract_decode_state(cfg: ArchConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len))


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Every input of the lowered step for this (arch, shape) cell."""
    out: Dict[str, Any] = {"batch": batch_specs(cfg, shape)}
    if shape.kind == "train":
        out["state"] = abstract_train_state(cfg)
    elif shape.kind == "decode":
        out["params"] = abstract_params(cfg)
        out["decode_state"] = abstract_decode_state(cfg, shape)
    else:  # prefill
        out["params"] = abstract_params(cfg)
    return out
