"""End-to-end training driver: data pipeline -> jitted train step ->
checkpointing -> fault handling -> (optional) LEO analysis of the compiled
step.

On this CPU container it drives reduced configs (`--smoke`) on a host mesh;
on real pods the same driver runs the production mesh (the dry-run proves
those configs lower/compile).  Example:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --batch 8 --seq 64 --checkpoint-dir /tmp/ckpt --analyze
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def build(arch: str, smoke: bool, batch: int, seq: int, mesh,
          microbatch: int = 1, grad_compression: bool = False,
          steps: int = 0, lr: float = 0.0):
    from ..configs import get_config, smoke_config
    from ..data.pipeline import DataPipeline
    from ..data.synthetic import SyntheticConfig, SyntheticTokenDataset
    from ..optim import AdamWConfig
    from ..parallel.sharding import ShardingRules
    from ..runtime.steps import TrainOptions, init_train_state, \
        make_train_step

    cfg = get_config(arch)
    if smoke:
        cfg = smoke_config(cfg)
    rules = ShardingRules(mesh, cfg)

    state = init_train_state(jax.random.PRNGKey(0), cfg)
    pspecs = rules.param_specs(state["params"])
    ospecs = rules.opt_specs(state["opt"], state["params"])
    state_specs = {"params": pspecs, "opt": ospecs, "step": P()}
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                            is_leaf=lambda x: isinstance(x, P))
    state = jax.tree.map(lambda a, s: jax.device_put(a, s), state, state_sh)

    dp = rules.dp_spec
    batch_sharding = {
        "tokens": NamedSharding(mesh, P(dp, None)),
        "labels": NamedSharding(mesh, P(dp, None)),
        "embeds": NamedSharding(mesh, P(dp, None, None)),
    }
    ds = SyntheticTokenDataset(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, d_model=cfg.d_model,
        frontend=cfg.frontend))
    pipeline = DataPipeline(ds, batch, shardings=batch_sharding)

    # The TrainOptions schedule defaults (100-step warmup over a 10k-step
    # horizon) are production-run constants; a short run that never leaves
    # warmup makes no measurable progress.  Scale the schedule to the run
    # that was actually requested.
    if steps > 0:
        warmup = max(1, min(100, steps // 10))
        total = steps
    else:
        warmup, total = 100, 10_000
    options = TrainOptions(remat="group", chunk=min(512, seq),
                           microbatch=microbatch,
                           grad_compression=grad_compression,
                           warmup_steps=warmup, total_steps=total)
    # Smoke configs are tiny (d_model 64); the production 3e-4 moves them
    # too slowly to beat per-batch loss noise inside a smoke-length run.
    if lr <= 0.0:
        lr = 3e-3 if smoke else AdamWConfig().lr
    opt_cfg = AdamWConfig(lr=lr)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, options=options),
                      donate_argnums=(0,))
    return cfg, state, state_sh, pipeline, step_fn


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.0,
                    help="peak learning rate (0 = auto: 3e-3 smoke, "
                         "3e-4 production)")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--analyze", action="store_true",
                    help="run LEO on the compiled train step")
    args = ap.parse_args(argv)

    from .mesh import make_host_mesh
    mesh = make_host_mesh(model_parallel=args.model_parallel)

    with mesh:
        cfg, state, state_sh, pipeline, step_fn = build(
            args.arch, args.smoke, args.batch, args.seq, mesh,
            microbatch=args.microbatch,
            grad_compression=args.grad_compression,
            steps=args.steps, lr=args.lr)

        manager = None
        start_step = 0
        if args.checkpoint_dir:
            from ..checkpoint.manager import CheckpointManager
            manager = CheckpointManager(args.checkpoint_dir, keep=3)
            if args.restore and manager.has_checkpoint():
                state, start_step = manager.restore_latest(
                    state, shardings=state_sh)
                print(f"restored from step {start_step}")

        losses = []
        t0 = time.time()
        it = pipeline(start_step)
        for step in range(start_step, args.steps):
            batch = next(it)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if manager and (step + 1) % args.checkpoint_every == 0:
                manager.save(step + 1, state)
        if manager:
            manager.save(args.steps, state)
            manager.wait()
        wall = time.time() - t0

        result = {"final_loss": losses[-1], "first_loss": losses[0],
                  "steps": args.steps - start_step, "wall_seconds": wall}
        print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"({result['steps']} steps, {wall:.1f}s)")

        if args.analyze:
            from ..core import LeoSession
            from ..launch import specs as S
            lowered = jax.jit(step_fn.__wrapped__).lower(
                jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             state),
                jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             pipeline.device_batch(0)))
            an = LeoSession().analyze(lowered.compile().as_text(),
                                      backend="tpu_v5e")
            print(an.summary())
            result["leo_step_seconds"] = an.estimated_step_seconds
        return result


if __name__ == "__main__":
    main()
