"""Queue-driven analysis server: "analysis as a service" as an entry point.

The LEO analogue of `launch/serve.py`'s token-serving engine, mirroring its
slot pattern: :class:`AnalyzeRequest`s (HLO traces plus analysis knobs)
queue into a fixed pool of worker slots; each engine tick admits queued
requests to free slots (dispatching them onto the shared
:class:`~repro.core.service.LeoService` thread pool) and harvests finished
:class:`~repro.core.report.Diagnosis` results.  The service's single-flight
caches mean N queued requests for the same trace cost one parse and one
pipeline run, and a warm ``--cache-dir`` serves repeat traffic from disk
without parsing at all.

The engine is thread-safe and is the execution half of the networked
front-end in :mod:`repro.serve`: ``--serve PORT`` wraps it in the HTTP
server (bounded admission with 429 shed, per-request deadlines,
``/metrics``, graceful SIGTERM drain — see ``docs/serving.md``).
``max_queue`` bounds admission (:class:`QueueFull` when exceeded), each
queued request may carry an absolute deadline (overdue entries are
cancelled in the queue or abandoned in flight), and every result records
``queue_seconds`` (submit→admit) and ``service_seconds`` (admit→done)
separately.

Usage (smoke: built-in demo traces, 3 slots):

  PYTHONPATH=src python -m repro.launch.analysis_server --smoke

  PYTHONPATH=src python -m repro.launch.analysis_server \\
      --hlo experiments/dryrun/qwen2__train_4k__single.hlo.gz \\
      --backends tpu_v5e,nvidia_gh200,amd_mi300a --cache-dir .leo_cache

  PYTHONPATH=src python -m repro.launch.analysis_server \\
      --serve 8321 --slots 4 --max-queue 16 --cache-dir .leo_cache
"""
from __future__ import annotations

import argparse
import gzip
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional

from ..core import AnalyzeRequest, Diagnosis, LeoService


class QueueFull(RuntimeError):
    """Admission rejected: the bounded queue is at capacity.  The HTTP
    front-end maps this to 429 + ``Retry-After``."""

    def __init__(self, depth: int, limit: int):
        super().__init__(f"admission queue full ({depth}/{limit})")
        self.depth = depth
        self.limit = limit


class ServerDraining(RuntimeError):
    """Admission rejected: the server is draining (SIGTERM received);
    in-flight work finishes, new work goes elsewhere (HTTP 503)."""


@dataclass
class _Pending:
    """A queued request plus its transport envelope: when it arrived and
    when (monotonic clock) it stops being worth serving."""
    request: AnalyzeRequest
    submitted_at: float = 0.0
    deadline: Optional[float] = None       # absolute time.monotonic()


@dataclass
class _Slot:
    pending: Optional[_Pending] = None
    future: Optional[Future] = None
    admitted_at: float = 0.0


@dataclass
class ServerResult:
    request_id: str
    diagnosis: Optional[Diagnosis] = None      # single-backend requests
    fanout: Optional[Dict[str, Diagnosis]] = None  # multi-backend requests
    error: Optional[str] = None
    #: total submit→done wall time (= queue_seconds + service_seconds);
    #: kept for callers of the pre-split field
    seconds: float = 0.0
    queue_seconds: float = 0.0             # submit → admit (queue wait)
    service_seconds: float = 0.0           # admit → done (actual service)


class AnalysisServer:
    """Slot-based continuous batching over `LeoService.submit`.

    Deliberately the same shape as ``ServeEngine``: ``submit`` enqueues,
    ``tick`` fills free slots and harvests completions, ``run`` loops
    until drained.  Slots bound the number of in-flight analyses
    independently of queue depth — the admission-control half of a
    serving deployment, with the service pool as the execution half.

    Thread-safe: the HTTP front-end submits from N handler threads and
    waits per-request on :meth:`wait` while a background ticker (see
    :meth:`start_ticker`) drives admissions/harvests; the single-threaded
    ``submit``/``run`` smoke path is unchanged.
    """

    def __init__(self, service: Optional[LeoService] = None,
                 slots: int = 4, max_queue: Optional[int] = None):
        self.service = service or LeoService(max_workers=max(slots, 2))
        self.slots = [_Slot() for _ in range(slots)]
        self.max_queue = max_queue
        self.queue: List[_Pending] = []
        self.results: Dict[str, ServerResult] = {}
        self._auto_rid = 0
        self._lock = threading.RLock()
        self._done = threading.Condition(self._lock)
        self._draining = False
        self._abandoned: set = set()
        self._ticker: Optional[threading.Thread] = None
        self._ticker_stop = threading.Event()

    def submit(self, request: AnalyzeRequest,
               deadline_seconds: Optional[float] = None) -> str:
        """Enqueue one request.  Raises :class:`QueueFull` when the
        bounded queue is at capacity and :class:`ServerDraining` after
        :meth:`begin_drain` — admission control, not silent buffering."""
        request.validate()
        now = time.monotonic()
        with self._lock:
            if self._draining:
                raise ServerDraining("server is draining; not admitting")
            if self.max_queue is not None and \
                    len(self.queue) >= self.max_queue:
                raise QueueFull(len(self.queue), self.max_queue)
            if request.request_id is None:
                request.request_id = f"req-{self._auto_rid}"
                self._auto_rid += 1
            self.queue.append(_Pending(
                request=request, submitted_at=now,
                deadline=now + deadline_seconds
                if deadline_seconds is not None else None))
            return request.request_id

    @property
    def active(self) -> bool:
        with self._lock:
            return bool(self.queue) or any(s.pending for s in self.slots)

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self.queue)

    @property
    def in_flight(self) -> int:
        with self._lock:
            return sum(1 for s in self.slots if s.pending is not None)

    def _finish(self, rid: str, res: ServerResult) -> None:
        # caller holds the lock; abandoned requests' results are dropped
        # (their waiter already gave up — retaining them would leak)
        if rid in self._abandoned:
            self._abandoned.discard(rid)
            return
        self.results[rid] = res

    def _expire_queued(self, now: float) -> int:
        """Cancel queued requests whose deadline passed before a slot
        freed up: they complete as ``deadline_exceeded`` errors without
        ever occupying a slot."""
        expired = 0
        keep: List[_Pending] = []
        for pending in self.queue:
            if pending.deadline is not None and now > pending.deadline:
                waited = now - pending.submitted_at
                self._finish(pending.request.request_id, ServerResult(
                    request_id=pending.request.request_id,
                    error=f"deadline_exceeded: cancelled after "
                          f"{waited:.3f}s in queue, never admitted",
                    seconds=waited, queue_seconds=waited))
                expired += 1
            else:
                keep.append(pending)
        if expired:
            self.queue[:] = keep
        return expired

    def _fill_slots(self, now: float) -> None:
        for slot in self.slots:
            if slot.pending is None and self.queue:
                pending = self.queue.pop(0)
                slot.pending = pending
                slot.admitted_at = now
                slot.future = self.service.submit_async(pending.request)

    def _harvest(self, now: float) -> int:
        done = 0
        for slot in self.slots:
            if slot.pending is None or not slot.future.done():
                continue
            pending = slot.pending
            rid = pending.request.request_id
            res = ServerResult(
                request_id=rid,
                queue_seconds=slot.admitted_at - pending.submitted_at,
                service_seconds=now - slot.admitted_at,
                seconds=now - pending.submitted_at)
            try:
                out = slot.future.result()
                if isinstance(out, dict):
                    res.fanout = out
                else:
                    res.diagnosis = out
            except Exception as e:  # noqa: BLE001 - report failures as results
                res.error = f"{type(e).__name__}: {e}"
            self._finish(rid, res)
            slot.pending = None
            slot.future = None
            done += 1
        return done

    def tick(self) -> int:
        """One engine step: expire overdue queued requests, admit to free
        slots, harvest completions.  Returns requests finished this tick
        (deadline cancellations included)."""
        with self._lock:
            now = time.monotonic()
            expired = self._expire_queued(now)
            self._fill_slots(now)
            done = expired + self._harvest(now)
            if done:
                self._done.notify_all()
            return done

    def run(self, poll_seconds: float = 0.005) -> Dict[str, ServerResult]:
        while self.active:
            if self.tick() == 0:
                time.sleep(poll_seconds)
        return self.results

    # -- front-end surface (the networked half consumes these) ----------------

    def wait(self, request_id: str,
             timeout: Optional[float] = None) -> Optional[ServerResult]:
        """Block until ``request_id`` finishes and pop its result; None on
        timeout (the caller decides whether to :meth:`abandon`)."""
        deadline = time.monotonic() + timeout if timeout is not None \
            else None
        with self._done:
            while request_id not in self.results:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                self._done.wait(remaining)
            return self.results.pop(request_id)

    def abandon(self, request_id: str) -> Optional[ServerResult]:
        """Give up on a request: drop it from the queue if still waiting,
        or mark it so its eventual result is discarded (the analysis
        itself is not interrupted — the service pool finishes and the
        warm cache keeps the work).  Returns the result if it raced in
        just before abandonment."""
        with self._lock:
            raced = self.results.pop(request_id, None)
            if raced is not None:
                return raced
            before = len(self.queue)
            self.queue[:] = [p for p in self.queue
                             if p.request.request_id != request_id]
            if len(self.queue) == before:        # queued nowhere: in flight
                self._abandoned.add(request_id)
            return None

    def begin_drain(self) -> None:
        """Stop admitting (``submit`` raises :class:`ServerDraining`);
        queued + in-flight work keeps going."""
        with self._lock:
            self._draining = True

    def drain(self, timeout: Optional[float] = None,
              poll_seconds: float = 0.01) -> bool:
        """`begin_drain` then wait until queued + in-flight work is
        finished.  True when fully drained; False on timeout.  Needs a
        running ticker (or an external ``tick()`` driver)."""
        self.begin_drain()
        deadline = time.monotonic() + timeout if timeout is not None \
            else None
        while self.active:
            if deadline is not None and time.monotonic() > deadline:
                return False
            if self._ticker is None:
                self.tick()
            time.sleep(poll_seconds)
        return True

    def start_ticker(self, poll_seconds: float = 0.002) -> None:
        """Run ``tick()`` on a daemon thread — the drive loop the HTTP
        front-end relies on while its handler threads block in
        :meth:`wait`."""
        if self._ticker is not None:
            return
        self._ticker_stop.clear()

        def loop() -> None:
            while not self._ticker_stop.is_set():
                if self.tick() == 0:
                    self._ticker_stop.wait(poll_seconds)

        self._ticker = threading.Thread(target=loop, daemon=True,
                                        name="leo-analysis-ticker")
        self._ticker.start()

    def stop_ticker(self) -> None:
        if self._ticker is None:
            return
        self._ticker_stop.set()
        self._ticker.join(timeout=5.0)
        self._ticker = None


# --------------------------------------------------------------------------
# Entry point.
# --------------------------------------------------------------------------

#: Format-valid demo trace (async collective + gather + while loop): the
#: features the stall taxonomy diverges on across vendors.
_DEMO_HLO = """\
HloModule demo_trace_{seed}

%body.1 (p.1: (s32[], f32[{n},{n}])) -> (s32[], f32[{n},{n}]) {{
  %p.1 = (s32[], f32[{n},{n}]) parameter(0)
  %iv = s32[] get-tuple-element(%p.1), index=0
  %one = s32[] constant(1)
  %iv2 = s32[] add(%iv, %one)
  %acc = f32[{n},{n}] get-tuple-element(%p.1), index=1
  %gain = f32[{n},{n}] multiply(%acc, %acc)
  ROOT %out = (s32[], f32[{n},{n}]) tuple(%iv2, %gain)
}}

%cond.1 (p.2: (s32[], f32[{n},{n}])) -> pred[] {{
  %p.2 = (s32[], f32[{n},{n}]) parameter(0)
  %iv3 = s32[] get-tuple-element(%p.2), index=0
  %lim = s32[] constant({trips})
  ROOT %lt = pred[] compare(%iv3, %lim), direction=LT
}}

ENTRY %main.1 (arg0: f32[{n},{n}], arg1: f32[{n},{n}]) -> f32[{n},{n}] {{
  %arg0 = f32[{n},{n}] parameter(0)
  %arg1 = f32[{n},{n}] parameter(1)
  %gather.1 = f32[{n},{n}] gather(%arg0, %arg1), metadata={{op_name="jit(step)/model/embed/gather"}}
  %ag-start = f32[{n},{n}] all-gather-start(%gather.1), channel_id=1, replica_groups=[2,4]<=[8], dimensions={{0}}, metadata={{op_name="jit(step)/model/layer/allgather"}}
  %indep = f32[{n},{n}] multiply(%arg1, %arg1)
  %ag-done = f32[{n},{n}] all-gather-done(%ag-start), metadata={{op_name="jit(step)/model/layer/allgather"}}
  %dot.1 = f32[{n},{n}] dot(%ag-done, %indep), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}, metadata={{op_name="jit(step)/model/layer/mlp/dot_general"}}
  %zero = s32[] constant(0)
  %init = (s32[], f32[{n},{n}]) tuple(%zero, %dot.1)
  %loop = (s32[], f32[{n},{n}]) while(%init), condition=%cond.1, body=%body.1
  %result = f32[{n},{n}] get-tuple-element(%loop), index=1
  ROOT %final = f32[{n},{n}] add(%result, %indep)
}}
"""


def demo_hlo(seed: int = 0, n: int = 128, trips: int = 5) -> str:
    return _DEMO_HLO.format(seed=seed, n=n, trips=trips)


def copy_storm_hlo(n_copies: int = 8, dim: int = 512) -> str:
    """Oversubscription demo trace (§III-E): `n_copies` async copies all
    in flight before any done — a double-buffered pipeline prologue
    cranked past some vendors' finite sync resources.  8 copies exceed
    NVIDIA-class named barriers (6) and AMD-class waitcnt counters (2)
    but fit Intel-class SWSB tokens (16) and TPU async contexts (32), so
    the same program serializes on some backends and not others.  Shared
    by `examples/crossvendor_divergence.py` and the divergence goldens
    (`tests/test_backend_divergence.py` pins snapshots of this exact
    trace — keep them in sync when changing it)."""
    lines = [f"  %arg{i} = f32[{dim},{dim}] parameter({i})"
             for i in range(n_copies)]
    for i in range(n_copies):
        lines.append(
            f"  %cp{i}-start = (f32[{dim},{dim}], f32[{dim},{dim}], u32[]) "
            f"copy-start(%arg{i}), "
            f'metadata={{op_name="jit(step)/model/io/copy{i}"}}')
    for i in range(n_copies):
        lines.append(
            f"  %cp{i}-done = f32[{dim},{dim}] copy-done(%cp{i}-start), "
            f'metadata={{op_name="jit(step)/model/io/copy{i}"}}')
    acc = "cp0-done"
    for i in range(1, n_copies):
        lines.append(f"  %s{i} = f32[{dim},{dim}] add(%{acc}, %cp{i}-done)")
        acc = f"s{i}"
    lines.append(f"  ROOT %out = f32[{dim},{dim}] negate(%{acc})")
    params = ", ".join(f"arg{i}: f32[{dim},{dim}]" for i in range(n_copies))
    return (f"HloModule fixture_copystorm\n\nENTRY %main.1 ({params}) -> "
            f"f32[{dim},{dim}] {{\n" + "\n".join(lines) + "\n}\n")


def wide_ops_hlo(n_streams: int = 12, depth: int = 3, dim: int = 256) -> str:
    """Wide independent-ops demo trace (the multi-stream issue fixture):
    `n_streams` dependency-free chains of `depth` elementwise/matmul ops,
    emitted round-robin so adjacent instructions belong to different
    chains.  Every chain is ready at t=0, so the program's ILP is bounded
    only by the backend's issue fabric: a narrow-issue part (4 queues)
    charges heavy `not_selected`/`pipe_busy` scheduler-contention cycles,
    a wide one (16 ports) issues the whole front cleanly, and a
    single-stream in-order part (TPU VLIW) structurally cannot emit those
    classes at all — the cross-vendor divergence the single-stream sampler
    could never show.  Chains alternate VPU (multiply) and MXU (dot) work
    so the contention splits between `not_selected` (arbitration loss to
    a different pipe) and `pipe_busy` (same pipe saturated).  Shared by
    the divergence goldens and the bench-smoke lane — keep them in sync
    when changing it."""
    lines = ["  %arg0 = f32[{d},{d}] parameter(0)".format(d=dim)]
    chains = []
    for i in range(n_streams):
        mxu = i % 2 == 1    # odd chains run on the matmul pipe
        ops = []
        prev = "arg0"
        for j in range(depth):
            name = f"c{i}_{j}"
            op = (f"  %{name} = f32[{dim},{dim}] "
                  + (f"dot(%{prev}, %{prev}), lhs_contracting_dims={{1}}, "
                     f"rhs_contracting_dims={{0}}"
                     if mxu else f"multiply(%{prev}, %{prev})")
                  + f', metadata={{op_name="jit(step)/wide/chain{i}/op{j}"}}')
            ops.append(op)
            prev = name
        chains.append(ops)
    # round-robin interleave: instruction k of every chain before k+1
    for j in range(max(len(c) for c in chains)):
        for c in chains:
            if j < len(c):
                lines.append(c[j])
    # reduction-tree tail joining the chains into one root
    acc = "c0_%d" % (depth - 1)
    for i in range(1, n_streams):
        lines.append(f"  %j{i} = f32[{dim},{dim}] "
                     f"add(%{acc}, %c{i}_{depth - 1})")
        acc = f"j{i}"
    lines.append(f"  ROOT %out = f32[{dim},{dim}] negate(%{acc})")
    return (f"HloModule fixture_wideops\n\nENTRY %main.1 "
            f"(arg0: f32[{dim},{dim}]) -> f32[{dim},{dim}] {{\n"
            + "\n".join(lines) + "\n}\n")


def _load_hlo(path: str) -> str:
    if path.endswith(".gz"):
        with gzip.open(path, "rt") as f:
            return f.read()
    with open(path) as f:
        return f.read()


def main(argv=None) -> Dict[str, ServerResult]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hlo", action="append", default=[],
                    help="HLO text file (.hlo or .hlo.gz); repeatable")
    ap.add_argument("--smoke", action="store_true",
                    help="use built-in demo traces (duplicates included, "
                         "to exercise single-flight dedup)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--backends", default="",
                    help="comma list; empty = service default backend, "
                         "'all' = fan out across every registered backend")
    ap.add_argument("--cache-dir", default=None,
                    help="content-addressed disk cache shared across runs")
    ap.add_argument("--hints-devices", type=int, default=8)
    ap.add_argument("--serve", type=int, default=None, metavar="PORT",
                    help="serve over HTTP on PORT (0 = ephemeral) instead "
                         "of running a one-shot batch; SIGTERM drains "
                         "gracefully (see docs/serving.md)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address for --serve")
    ap.add_argument("--max-queue", type=int, default=16,
                    help="bounded admission queue for --serve; full = "
                         "429 + Retry-After")
    ap.add_argument("--retry-after", type=float, default=0.25,
                    help="Retry-After seconds hinted on 429/503 sheds")
    ap.add_argument("--default-deadline", type=float, default=None,
                    help="deadline applied to --serve requests that do "
                         "not carry their own")
    ap.add_argument("--port-file", default=None,
                    help="write the bound --serve port to this file once "
                         "listening (how scripts find an ephemeral port)")
    ap.add_argument("--workers", type=int, default=1,
                    help="pre-forked worker processes for --serve; 1 "
                         "(default) serves in-process exactly as before, "
                         "N>1 binds once and forks N LeoHttpd workers "
                         "behind the listener (POSIX only)")
    ap.add_argument("--control-port", type=int, default=0,
                    help="with --workers N>1: port for the pool's "
                         "aggregated /metrics /stats /healthz /readyz "
                         "(0 = ephemeral)")
    ap.add_argument("--control-port-file", default=None,
                    help="write the bound control port to this file")
    args = ap.parse_args(argv)

    if args.serve is not None and args.workers > 1:
        # pre-forked multi-process serving: bind once, fork N workers,
        # rolling drain on SIGTERM (see repro.serve.pool)
        from ..serve.pool import LeoWorkerPool, serve_pool_forever
        pool = LeoWorkerPool(
            workers=args.workers, host=args.host, port=args.serve,
            slots=args.slots, max_queue=args.max_queue,
            retry_after_seconds=args.retry_after,
            default_deadline_seconds=args.default_deadline,
            cache_dir=args.cache_dir, control_port=args.control_port)
        pool.start()
        if args.port_file:
            with open(args.port_file, "w") as f:
                f.write(str(pool.port))
        if args.control_port_file and pool.control_port is not None:
            with open(args.control_port_file, "w") as f:
                f.write(str(pool.control_port))
        print(f"leo-serve pool listening on http://{args.host}:{pool.port} "
              f"({args.workers} workers x {args.slots} slots, "
              f"queue {args.max_queue}, control port {pool.control_port}); "
              f"SIGTERM drains rolling", flush=True)
        clean = serve_pool_forever(pool, install_signal_handlers=True)
        if not clean:
            print("leo-serve pool drain incomplete", flush=True)
            raise SystemExit(1)
        print("leo-serve drained cleanly", flush=True)
        return {}

    if args.serve is not None:
        # the networked front-end: stdlib HTTP around this engine's slots
        from ..serve.httpd import LeoHttpd, serve_forever
        from ..serve.metrics import MetricsRegistry
        metrics = MetricsRegistry()
        service = LeoService(cache_dir=args.cache_dir,
                             max_workers=max(args.slots, 2),
                             metrics=metrics)
        app = LeoHttpd(service=service, host=args.host, port=args.serve,
                       slots=args.slots, max_queue=args.max_queue,
                       retry_after_seconds=args.retry_after,
                       default_deadline_seconds=args.default_deadline,
                       metrics=metrics)
        if args.port_file:
            with open(args.port_file, "w") as f:
                f.write(str(app.port))
        print(f"leo-serve listening on http://{args.host}:{app.port} "
              f"({args.slots} slots, queue {args.max_queue}); "
              f"SIGTERM drains", flush=True)
        serve_forever(app)
        print("leo-serve drained cleanly", flush=True)
        return {}

    if not args.hlo and not args.smoke:
        ap.error("give --hlo file(s) or --smoke")

    texts = [_load_hlo(p) for p in args.hlo]
    if args.smoke:
        # fewer distinct traces than requests: repeats collapse in-cache
        texts += [demo_hlo(seed=i, n=128 + 32 * (i % 3))
                  for i in range(max(2, args.requests // 2))]

    backends = None
    fanout = False
    if args.backends == "all":
        fanout = True
    elif args.backends:
        names = args.backends.split(",")
        backends, fanout = (names, True) if len(names) > 1 else (None, False)

    service = LeoService(cache_dir=args.cache_dir,
                         max_workers=max(args.slots, 2))
    server = AnalysisServer(service, slots=args.slots)
    hints = {"total_devices": args.hints_devices}
    for i in range(args.requests):
        req = AnalyzeRequest(hlo_text=texts[i % len(texts)], hints=hints)
        if fanout:
            req.backends = backends if backends is not None else \
                [b.name for b in service.session.backends]
        elif args.backends:
            req.backend = args.backends
        server.submit(req)

    t0 = time.perf_counter()
    results = server.run()
    wall = time.perf_counter() - t0

    errors = 0
    for rid in sorted(results, key=lambda r: int(r.split("-")[-1])):
        res = results[rid]
        if res.error is not None:
            errors += 1
            print(f"{rid}: ERROR {res.error}")
            continue
        diags = res.fanout if res.fanout is not None \
            else {"": res.diagnosis}
        for d in diags.values():
            top = d.root_causes[0]["instruction"] if d.root_causes else "-"
            print(f"{rid} [{d.backend}]: "
                  f"est {d.estimated_step_seconds*1e6:9.1f} us, "
                  f"queued {res.queue_seconds*1e3:6.1f} ms + "
                  f"service {res.service_seconds*1e3:7.1f} ms, "
                  f"top root cause: {top}")
    stats = service.stats_dict()
    ok = [r for r in results.values() if r.error is None]
    if ok:
        mean_q = sum(r.queue_seconds for r in ok) / len(ok)
        mean_s = sum(r.service_seconds for r in ok) / len(ok)
        print(f"\nmean queue wait {mean_q*1e3:.1f} ms, "
              f"mean service {mean_s*1e3:.1f} ms over {len(ok)} ok")
    print(f"{len(results)} requests via {len(server.slots)} slots in "
          f"{wall:.2f}s; parses: {stats['parse_calls']} calls -> "
          f"{service.stats.parse_misses} actual "
          f"(+{stats['parse_disk_hits']} from disk), "
          f"analyses: {stats['analyze_calls']} calls -> "
          f"{stats['analyze_calls'] - stats['analyze_hits']} runs")
    if errors:
        raise SystemExit(f"{errors} request(s) failed")
    service.close()
    return results


if __name__ == "__main__":
    main()
