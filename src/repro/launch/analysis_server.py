"""Queue-driven analysis server: "analysis as a service" as an entry point.

The LEO analogue of `launch/serve.py`'s token-serving engine, mirroring its
slot pattern: :class:`AnalyzeRequest`s (HLO traces plus analysis knobs)
queue into a fixed pool of worker slots; each engine tick admits queued
requests to free slots (dispatching them onto the shared
:class:`~repro.core.service.LeoService` thread pool) and harvests finished
:class:`~repro.core.report.Diagnosis` results.  The service's single-flight
caches mean N queued requests for the same trace cost one parse and one
pipeline run, and a warm ``--cache-dir`` serves repeat traffic from disk
without parsing at all.

Usage (smoke: built-in demo traces, 3 slots):

  PYTHONPATH=src python -m repro.launch.analysis_server --smoke

  PYTHONPATH=src python -m repro.launch.analysis_server \\
      --hlo experiments/dryrun/qwen2__train_4k__single.hlo.gz \\
      --backends tpu_v5e,nvidia_gh200,amd_mi300a --cache-dir .leo_cache
"""
from __future__ import annotations

import argparse
import gzip
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional

from ..core import AnalyzeRequest, Diagnosis, LeoService


@dataclass
class _Slot:
    request: Optional[AnalyzeRequest] = None
    future: Optional[Future] = None
    admitted_at: float = 0.0


@dataclass
class ServerResult:
    request_id: str
    diagnosis: Optional[Diagnosis] = None      # single-backend requests
    fanout: Optional[Dict[str, Diagnosis]] = None  # multi-backend requests
    error: Optional[str] = None
    seconds: float = 0.0


class AnalysisServer:
    """Slot-based continuous batching over `LeoService.submit`.

    Deliberately the same shape as ``ServeEngine``: ``submit`` enqueues,
    ``tick`` fills free slots and harvests completions, ``run`` loops
    until drained.  Slots bound the number of in-flight analyses
    independently of queue depth — the admission-control half of a
    serving deployment, with the service pool as the execution half.
    """

    def __init__(self, service: Optional[LeoService] = None,
                 slots: int = 4):
        self.service = service or LeoService(max_workers=max(slots, 2))
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: List[AnalyzeRequest] = []
        self.results: Dict[str, ServerResult] = {}
        self._auto_rid = 0

    def submit(self, request: AnalyzeRequest) -> str:
        request.validate()
        if request.request_id is None:
            request.request_id = f"req-{self._auto_rid}"
            self._auto_rid += 1
        self.queue.append(request)
        return request.request_id

    @property
    def active(self) -> bool:
        return bool(self.queue) or any(s.request for s in self.slots)

    def _fill_slots(self) -> None:
        for slot in self.slots:
            if slot.request is None and self.queue:
                req = self.queue.pop(0)
                slot.request = req
                slot.admitted_at = time.perf_counter()
                slot.future = self.service.submit_async(req)

    def _harvest(self) -> int:
        done = 0
        for slot in self.slots:
            if slot.request is None or not slot.future.done():
                continue
            rid = slot.request.request_id
            res = ServerResult(
                request_id=rid,
                seconds=time.perf_counter() - slot.admitted_at)
            try:
                out = slot.future.result()
                if isinstance(out, dict):
                    res.fanout = out
                else:
                    res.diagnosis = out
            except Exception as e:  # noqa: BLE001 - report failures as results
                res.error = f"{type(e).__name__}: {e}"
            self.results[rid] = res
            slot.request = None
            slot.future = None
            done += 1
        return done

    def tick(self) -> int:
        """One engine step: admit queued requests, harvest completions.
        Returns the number of requests finished this tick."""
        self._fill_slots()
        return self._harvest()

    def run(self, poll_seconds: float = 0.005) -> Dict[str, ServerResult]:
        while self.active:
            if self.tick() == 0:
                time.sleep(poll_seconds)
        return self.results


# --------------------------------------------------------------------------
# Entry point.
# --------------------------------------------------------------------------

#: Format-valid demo trace (async collective + gather + while loop): the
#: features the stall taxonomy diverges on across vendors.
_DEMO_HLO = """\
HloModule demo_trace_{seed}

%body.1 (p.1: (s32[], f32[{n},{n}])) -> (s32[], f32[{n},{n}]) {{
  %p.1 = (s32[], f32[{n},{n}]) parameter(0)
  %iv = s32[] get-tuple-element(%p.1), index=0
  %one = s32[] constant(1)
  %iv2 = s32[] add(%iv, %one)
  %acc = f32[{n},{n}] get-tuple-element(%p.1), index=1
  %gain = f32[{n},{n}] multiply(%acc, %acc)
  ROOT %out = (s32[], f32[{n},{n}]) tuple(%iv2, %gain)
}}

%cond.1 (p.2: (s32[], f32[{n},{n}])) -> pred[] {{
  %p.2 = (s32[], f32[{n},{n}]) parameter(0)
  %iv3 = s32[] get-tuple-element(%p.2), index=0
  %lim = s32[] constant({trips})
  ROOT %lt = pred[] compare(%iv3, %lim), direction=LT
}}

ENTRY %main.1 (arg0: f32[{n},{n}], arg1: f32[{n},{n}]) -> f32[{n},{n}] {{
  %arg0 = f32[{n},{n}] parameter(0)
  %arg1 = f32[{n},{n}] parameter(1)
  %gather.1 = f32[{n},{n}] gather(%arg0, %arg1), metadata={{op_name="jit(step)/model/embed/gather"}}
  %ag-start = f32[{n},{n}] all-gather-start(%gather.1), channel_id=1, replica_groups=[2,4]<=[8], dimensions={{0}}, metadata={{op_name="jit(step)/model/layer/allgather"}}
  %indep = f32[{n},{n}] multiply(%arg1, %arg1)
  %ag-done = f32[{n},{n}] all-gather-done(%ag-start), metadata={{op_name="jit(step)/model/layer/allgather"}}
  %dot.1 = f32[{n},{n}] dot(%ag-done, %indep), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}, metadata={{op_name="jit(step)/model/layer/mlp/dot_general"}}
  %zero = s32[] constant(0)
  %init = (s32[], f32[{n},{n}]) tuple(%zero, %dot.1)
  %loop = (s32[], f32[{n},{n}]) while(%init), condition=%cond.1, body=%body.1
  %result = f32[{n},{n}] get-tuple-element(%loop), index=1
  ROOT %final = f32[{n},{n}] add(%result, %indep)
}}
"""


def demo_hlo(seed: int = 0, n: int = 128, trips: int = 5) -> str:
    return _DEMO_HLO.format(seed=seed, n=n, trips=trips)


def copy_storm_hlo(n_copies: int = 8, dim: int = 512) -> str:
    """Oversubscription demo trace (§III-E): `n_copies` async copies all
    in flight before any done — a double-buffered pipeline prologue
    cranked past some vendors' finite sync resources.  8 copies exceed
    NVIDIA-class named barriers (6) and AMD-class waitcnt counters (2)
    but fit Intel-class SWSB tokens (16) and TPU async contexts (32), so
    the same program serializes on some backends and not others.  Shared
    by `examples/crossvendor_divergence.py` and the divergence goldens
    (`tests/test_backend_divergence.py` pins snapshots of this exact
    trace — keep them in sync when changing it)."""
    lines = [f"  %arg{i} = f32[{dim},{dim}] parameter({i})"
             for i in range(n_copies)]
    for i in range(n_copies):
        lines.append(
            f"  %cp{i}-start = (f32[{dim},{dim}], f32[{dim},{dim}], u32[]) "
            f"copy-start(%arg{i}), "
            f'metadata={{op_name="jit(step)/model/io/copy{i}"}}')
    for i in range(n_copies):
        lines.append(
            f"  %cp{i}-done = f32[{dim},{dim}] copy-done(%cp{i}-start), "
            f'metadata={{op_name="jit(step)/model/io/copy{i}"}}')
    acc = "cp0-done"
    for i in range(1, n_copies):
        lines.append(f"  %s{i} = f32[{dim},{dim}] add(%{acc}, %cp{i}-done)")
        acc = f"s{i}"
    lines.append(f"  ROOT %out = f32[{dim},{dim}] negate(%{acc})")
    params = ", ".join(f"arg{i}: f32[{dim},{dim}]" for i in range(n_copies))
    return (f"HloModule fixture_copystorm\n\nENTRY %main.1 ({params}) -> "
            f"f32[{dim},{dim}] {{\n" + "\n".join(lines) + "\n}\n")


def wide_ops_hlo(n_streams: int = 12, depth: int = 3, dim: int = 256) -> str:
    """Wide independent-ops demo trace (the multi-stream issue fixture):
    `n_streams` dependency-free chains of `depth` elementwise/matmul ops,
    emitted round-robin so adjacent instructions belong to different
    chains.  Every chain is ready at t=0, so the program's ILP is bounded
    only by the backend's issue fabric: a narrow-issue part (4 queues)
    charges heavy `not_selected`/`pipe_busy` scheduler-contention cycles,
    a wide one (16 ports) issues the whole front cleanly, and a
    single-stream in-order part (TPU VLIW) structurally cannot emit those
    classes at all — the cross-vendor divergence the single-stream sampler
    could never show.  Chains alternate VPU (multiply) and MXU (dot) work
    so the contention splits between `not_selected` (arbitration loss to
    a different pipe) and `pipe_busy` (same pipe saturated).  Shared by
    the divergence goldens and the bench-smoke lane — keep them in sync
    when changing it."""
    lines = ["  %arg0 = f32[{d},{d}] parameter(0)".format(d=dim)]
    chains = []
    for i in range(n_streams):
        mxu = i % 2 == 1    # odd chains run on the matmul pipe
        ops = []
        prev = "arg0"
        for j in range(depth):
            name = f"c{i}_{j}"
            op = (f"  %{name} = f32[{dim},{dim}] "
                  + (f"dot(%{prev}, %{prev}), lhs_contracting_dims={{1}}, "
                     f"rhs_contracting_dims={{0}}"
                     if mxu else f"multiply(%{prev}, %{prev})")
                  + f', metadata={{op_name="jit(step)/wide/chain{i}/op{j}"}}')
            ops.append(op)
            prev = name
        chains.append(ops)
    # round-robin interleave: instruction k of every chain before k+1
    for j in range(max(len(c) for c in chains)):
        for c in chains:
            if j < len(c):
                lines.append(c[j])
    # reduction-tree tail joining the chains into one root
    acc = "c0_%d" % (depth - 1)
    for i in range(1, n_streams):
        lines.append(f"  %j{i} = f32[{dim},{dim}] "
                     f"add(%{acc}, %c{i}_{depth - 1})")
        acc = f"j{i}"
    lines.append(f"  ROOT %out = f32[{dim},{dim}] negate(%{acc})")
    return (f"HloModule fixture_wideops\n\nENTRY %main.1 "
            f"(arg0: f32[{dim},{dim}]) -> f32[{dim},{dim}] {{\n"
            + "\n".join(lines) + "\n}\n")


def _load_hlo(path: str) -> str:
    if path.endswith(".gz"):
        with gzip.open(path, "rt") as f:
            return f.read()
    with open(path) as f:
        return f.read()


def main(argv=None) -> Dict[str, ServerResult]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hlo", action="append", default=[],
                    help="HLO text file (.hlo or .hlo.gz); repeatable")
    ap.add_argument("--smoke", action="store_true",
                    help="use built-in demo traces (duplicates included, "
                         "to exercise single-flight dedup)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--backends", default="",
                    help="comma list; empty = service default backend, "
                         "'all' = fan out across every registered backend")
    ap.add_argument("--cache-dir", default=None,
                    help="content-addressed disk cache shared across runs")
    ap.add_argument("--hints-devices", type=int, default=8)
    args = ap.parse_args(argv)

    if not args.hlo and not args.smoke:
        ap.error("give --hlo file(s) or --smoke")

    texts = [_load_hlo(p) for p in args.hlo]
    if args.smoke:
        # fewer distinct traces than requests: repeats collapse in-cache
        texts += [demo_hlo(seed=i, n=128 + 32 * (i % 3))
                  for i in range(max(2, args.requests // 2))]

    backends = None
    fanout = False
    if args.backends == "all":
        fanout = True
    elif args.backends:
        names = args.backends.split(",")
        backends, fanout = (names, True) if len(names) > 1 else (None, False)

    service = LeoService(cache_dir=args.cache_dir,
                         max_workers=max(args.slots, 2))
    server = AnalysisServer(service, slots=args.slots)
    hints = {"total_devices": args.hints_devices}
    for i in range(args.requests):
        req = AnalyzeRequest(hlo_text=texts[i % len(texts)], hints=hints)
        if fanout:
            req.backends = backends if backends is not None else \
                [b.name for b in service.session.backends]
        elif args.backends:
            req.backend = args.backends
        server.submit(req)

    t0 = time.perf_counter()
    results = server.run()
    wall = time.perf_counter() - t0

    errors = 0
    for rid in sorted(results, key=lambda r: int(r.split("-")[-1])):
        res = results[rid]
        if res.error is not None:
            errors += 1
            print(f"{rid}: ERROR {res.error}")
            continue
        diags = res.fanout if res.fanout is not None \
            else {"": res.diagnosis}
        for d in diags.values():
            top = d.root_causes[0]["instruction"] if d.root_causes else "-"
            print(f"{rid} [{d.backend}]: "
                  f"est {d.estimated_step_seconds*1e6:9.1f} us, "
                  f"top root cause: {top}")
    stats = service.stats_dict()
    print(f"\n{len(results)} requests via {len(server.slots)} slots in "
          f"{wall:.2f}s; parses: {stats['parse_calls']} calls -> "
          f"{service.stats.parse_misses} actual "
          f"(+{stats['parse_disk_hits']} from disk), "
          f"analyses: {stats['analyze_calls']} calls -> "
          f"{stats['analyze_calls'] - stats['analyze_hits']} runs")
    if errors:
        raise SystemExit(f"{errors} request(s) failed")
    service.close()
    return results


if __name__ == "__main__":
    main()
