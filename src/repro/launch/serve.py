"""Batched serving driver: slot-based continuous batching over decode_step.

Requests (token prompts) fill a fixed pool of batch slots; each engine tick
decodes one token for every active slot; finished sequences release their
slot to queued requests.  Prompts enter via teacher-forced decode of their
tokens (prefill-by-decode keeps one compiled program — appropriate at smoke
scale; the prefill-shape dry-run covers the batched-prefill path).
"""
from __future__ import annotations

import argparse
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class _Slot:
    request: Optional[Request] = None
    pos: int = 0
    feed_idx: int = 0   # how much of the prompt is consumed


class ServeEngine:
    def __init__(self, cfg, params, batch_slots: int, max_len: int):
        from ..models import init_decode_state
        from ..runtime.steps import make_serve_step

        self.cfg = cfg
        self.params = params
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.state = init_decode_state(cfg, batch_slots, max_len)
        # pristine single-slot state, written into a slot at admission:
        # recurrent mixers (SSM/xLSTM) carry hidden state across tokens,
        # so a reused slot must not leak its previous occupant's state
        # into the next request (KV slots are safe via position masking,
        # but they are reset too — it is the same write)
        self._fresh_state = init_decode_state(cfg, 1, max_len)
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: List[Request] = []
        # per-slot position vector: a freed slot re-admits at pos=0 while
        # its neighbors keep decoding mid-stream (continuous batching
        # without the old pos=0 admission-alignment restriction)
        self._step = jax.jit(make_serve_step(cfg, per_slot_pos=True),
                             donate_argnums=(1,))

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def _reset_slot_state(self, idx: int) -> None:
        """Overwrite batch slot `idx` (axis 1 of every (L, B, ...) state
        leaf) with freshly-initialized decode state."""
        self.state = jax.tree.map(
            lambda st, fresh: st.at[:, idx].set(
                fresh[:, 0].astype(st.dtype)),
            self.state, self._fresh_state)

    def _fill_slots(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.request is None and self.queue:
                slot.request = self.queue.pop(0)
                slot.pos = 0
                slot.feed_idx = 0
                self._reset_slot_state(i)

    @property
    def active(self) -> bool:
        return bool(self.queue) or any(s.request for s in self.slots)

    def tick(self) -> None:
        """One engine step: feed prompt token or consume generated token."""
        self._fill_slots()
        tokens = np.zeros((self.batch_slots,), np.int32)
        pos = np.zeros((self.batch_slots,), np.int32)
        for i, slot in enumerate(self.slots):
            pos[i] = slot.pos
            r = slot.request
            if r is None:
                continue
            if slot.feed_idx < len(r.prompt):
                tokens[i] = r.prompt[slot.feed_idx]
            else:
                tokens[i] = r.generated[-1] if r.generated else 0
        next_tok, logits, self.state = self._step(
            self.params, self.state, jnp.asarray(tokens), jnp.asarray(pos))
        next_tok = np.asarray(next_tok)
        for i, slot in enumerate(self.slots):
            r = slot.request
            if r is None:
                continue
            slot.pos += 1
            if slot.feed_idx < len(r.prompt):
                slot.feed_idx += 1
                if slot.feed_idx == len(r.prompt):
                    r.generated.append(int(next_tok[i]))
            else:
                r.generated.append(int(next_tok[i]))
            if len(r.generated) >= r.max_new_tokens or \
                    slot.pos >= self.max_len - 1:
                r.done = True
                slot.request = None

    def run(self) -> None:
        while self.active:
            self.tick()


def main(argv=None) -> Dict[int, List[int]]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    from ..configs import get_config, smoke_config
    from ..models import init_params

    cfg = smoke_config(get_config(args.arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, args.slots, args.max_len)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(0, cfg.vocab_size, size=4)),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    out = {r.rid: r.generated for r in reqs}
    for rid, toks in out.items():
        print(f"request {rid}: {len(toks)} tokens: {toks[:8]}...")
    return out


if __name__ == "__main__":
    main()
