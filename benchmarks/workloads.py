"""RAJAPerf-analogue workload suite (paper Table IV reproduction).

Each workload provides a *baseline* and a *LEO-guided optimized* variant —
the optimization confined to the code region LEO's top chain implicates
(§V-B's restrictive protocol).  Variants are compiled separately; LEO's
shared cost model supplies estimated kernel times per hardware backend, so
speedups are model-time ratios (this container has no TPU wall clock).

`kernels` may be a list of >1 jitted stages (PRESSURE/ENERGY): stages model
separate kernel launches whose intermediate tensors round-trip HBM — the
paper's inter-kernel-traffic cases, measured by summing per-stage times
(+ the intermediate traffic between them).

`fix_action` names the LEO recommendation action id that *is* the fix —
consumed by the Table-V context study.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SDS = jax.ShapeDtypeStruct
KEY = jax.random.PRNGKey(0)


@dataclass
class Workload:
    name: str
    baseline: List[Tuple[Callable, Tuple]]     # [(fn, example_args)]
    optimized: List[Tuple[Callable, Tuple]]
    fix_action: str          # primary fix (reporting)
    accept_actions: Tuple[str, ...] = ()   # action ids counted as a hit
    source: str = ""                           # kernel source shown to the
                                               # Table-V optimizers


def _f(shape, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


# -- LTIMES family: strided 3-tensor contraction --------------------------------

_NM, _D, _G, _Z = 32, 64, 32, 128


def _ltimes_baseline(ell, psi):
    # chunked loop over d accumulating rank-8 updates: low arithmetic
    # intensity, the phi accumulator round-trips HBM every chunk
    def body(phi, d0):
        chunk = jax.lax.dynamic_slice(psi, (d0, 0, 0), (8, _G, _Z))
        ecol = jax.lax.dynamic_slice(ell, (0, d0), (_NM, 8))
        phi = phi + jnp.einsum("mc,cgz->mgz", ecol, chunk)
        return phi, ()
    phi0 = jnp.zeros((_NM, _G, _Z), jnp.float32)
    phi, _ = jax.lax.scan(body, phi0, jnp.arange(0, _D, 8))
    return phi


def _ltimes_optimized(ell, psi):
    # single MXU contraction (the "tile into SMEM/LDS" analogue: one
    # dot_general keeps the accumulator on-chip)
    return jnp.einsum("md,dgz->mgz", ell, psi.reshape(_D, _G, _Z),
                      preferred_element_type=jnp.float32)


def _make_ltimes(name):
    ell = _f((_NM, _D))
    psi = _f((_D, _G, _Z), seed=1)
    return Workload(
        name=name,
        baseline=[(jax.jit(_ltimes_baseline), (ell, psi))],
        optimized=[(jax.jit(_ltimes_optimized), (ell, psi))],
        fix_action="pipeline_loop_iterations",
        accept_actions=("pipeline_loop_iterations", "tile_into_vmem",
                        "increase_matmul_intensity"),
        source="phi[m,g,z] += ell[m,d] * psi[d,g,z]  (loop over d)")


# -- GEMM / 2MM / 3MM ------------------------------------------------------------

_N = 512


def _gemm_naive(a, b):
    # 64-row blocks through a scan: B re-streams from HBM per block and
    # the skinny matmuls underfill the MXU
    def block(_, i):
        rows = jax.lax.dynamic_slice(a, (i * 64, 0), (64, a.shape[1]))
        return (), rows @ b
    _, blocks = jax.lax.scan(block, (), jnp.arange(a.shape[0] // 64))
    return blocks.reshape(a.shape[0], b.shape[1])


def _gemm_opt(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def _make_gemm():
    a, b = _f((_N, _N)), _f((_N, _N), seed=1)
    return Workload(
        "GEMM", [(jax.jit(_gemm_naive), (a, b))],
        [(jax.jit(_gemm_opt), (a, b))],
        fix_action="increase_matmul_intensity",
        accept_actions=("increase_matmul_intensity", "tile_into_vmem"),
        source="C[i,j] = sum_k A[i,k]*B[k,j] (row-at-a-time)")


def _make_mm(name, n_mats):
    mats = [_f((_N, _N), seed=i) for i in range(n_mats + 1)]

    def naive(*ms):
        out = ms[0]
        for m in ms[1:]:
            out = _gemm_naive(out, m)
        return out

    def opt(*ms):
        out = ms[0]
        for m in ms[1:]:
            out = _gemm_opt(out, m)
        return out

    return Workload(
        name, [(jax.jit(naive), tuple(mats))],
        [(jax.jit(opt), tuple(mats))],
        fix_action="increase_matmul_intensity",
        accept_actions=("increase_matmul_intensity", "tile_into_vmem"),
        source=f"{name}: chained {n_mats} matrix products")


# -- FIR: sliding window ---------------------------------------------------------

def _make_fir():
    n, taps = 1 << 16, 16
    x = _f((n,))
    coeff = _f((taps,), seed=2)

    def baseline(x, c):
        # gathers a window per output element (irregular loads)
        idx = jnp.arange(n - taps)[:, None] + jnp.arange(taps)[None, :]
        return (x[idx] * c[None, :]).sum(-1)

    def optimized(x, c):
        # contiguous shifted slices (coalesced)
        out = jnp.zeros((n - taps,), jnp.float32)
        for t in range(taps):
            out = out + c[t] * jax.lax.dynamic_slice(x, (t,), (n - taps,))
        return out

    return Workload(
        "FIR", [(jax.jit(baseline), (x, coeff))],
        [(jax.jit(optimized), (x, coeff))],
        fix_action="coalesce_or_tile_gather",
        accept_actions=("coalesce_or_tile_gather",),
        source="y[i] = sum_t c[t] * x[i+t]")


# -- PRESSURE / ENERGY: kernel fusion --------------------------------------------

def _make_fusion(name, n_stages):
    n = 1 << 20
    x = _f((n,))

    def stage(i):
        def f(v):
            return jnp.tanh(v) * 1.01 + 0.01 * i
        return jax.jit(f)

    def fused(v):
        for i in range(n_stages):
            v = jnp.tanh(v) * 1.01 + 0.01 * i
        return v

    return Workload(
        name,
        baseline=[(stage(i), (x,)) for i in range(n_stages)],
        optimized=[(jax.jit(fused), (x,))],
        fix_action="fuse_kernels",
        accept_actions=("fuse_kernels",),
        source=f"{name}: {n_stages} elementwise kernels launched "
               "back-to-back over the same field")


# -- VOL3D / ZONAL_ACCUM: pointer indirection ------------------------------------

def _make_indirect(name, n_ptrs):
    n = 1 << 14
    x = _f((n + 8,))
    # "pointers": precomputed index arrays (x8) vs base+stride arithmetic
    idxs = [np.arange(n) + k for k in range(n_ptrs)]
    idx_arrays = [jnp.asarray(i, jnp.int32) for i in idxs]

    def baseline(x, *idx):
        acc = jnp.zeros((n,), jnp.float32)
        for i in idx:
            acc = acc + x[i]          # gather per "pointer"
        return acc

    def optimized(x):
        acc = jnp.zeros((n,), jnp.float32)
        for k in range(n_ptrs):       # base + stride: contiguous slices
            acc = acc + jax.lax.dynamic_slice(x, (k,), (n,))
        return acc

    return Workload(
        name, [(jax.jit(baseline), (x, *idx_arrays))],
        [(jax.jit(optimized), (x,))],
        fix_action="coalesce_or_tile_gather",
        accept_actions=("coalesce_or_tile_gather",),
        source=f"{name}: {n_ptrs} indexed streams accumulated per zone")


# -- DEL_DOT_VEC_2D: reduction with limited headroom ------------------------------

def _make_reduction():
    n = 1 << 18
    x = _f((n,))

    def baseline(x):
        return jnp.sum(x * x)

    def optimized(x):   # same op: LEO should report little headroom
        return jnp.sum(jnp.square(x))

    return Workload(
        "DEL_DOT_VEC_2D", [(jax.jit(baseline), (x,))],
        [(jax.jit(optimized), (x,))],
        fix_action="already_compute_bound",
        accept_actions=("already_compute_bound", "tile_into_vmem"),
        source="norm-like reduction over the velocity field")


# -- MASS3DEA: recompute-vs-precompute basis products -----------------------------

def _make_mass3dea():
    q, d = 8, 64
    basis = _f((q, d))
    w = _f((q,), seed=3)

    def baseline(basis, w):
        # recompute basis products inside the contraction (transcendental
        # chain per element — the FP64 FMA chain analogue)
        def elem(i, acc):
            b = jnp.exp(jnp.log(jnp.abs(basis) + 1.0))  # wasteful recompute
            acc = acc + w[i] * (b[i][:, None] * b[i][None, :])
            return acc
        return jax.lax.fori_loop(0, q, elem,
                                 jnp.zeros((d, d), jnp.float32))

    def optimized(basis, w):
        # precompute the basis once, contract with one einsum
        return jnp.einsum("q,qd,qe->de", w, basis, basis,
                          preferred_element_type=jnp.float32)

    return Workload(
        "MASS3DEA", [(jax.jit(baseline), (basis, w))],
        [(jax.jit(optimized), (basis, w))],
        fix_action="pipeline_loop_iterations",
        accept_actions=("pipeline_loop_iterations", "tile_into_vmem",
                        "already_compute_bound"),
        source="mass-matrix assembly from basis-function products")


# -- MUL_MAT_Q (llama.cpp): indirect store -> direct ------------------------------

def _make_mulmatq():
    m, n, k = 256, 256, 256
    a = _f((m, k), jnp.bfloat16)
    b = _f((k, n), jnp.bfloat16, seed=1)
    ids = jnp.asarray(np.random.default_rng(0).permutation(m), jnp.int32)

    def baseline(a, b, ids):
        out = jnp.dot(a, b, preferred_element_type=jnp.float32)
        return jnp.zeros_like(out).at[ids].set(out)   # indirect store

    def optimized(a, b):
        return jnp.dot(a, b, preferred_element_type=jnp.float32)  # direct

    return Workload(
        "MUL_MAT_Q", [(jax.jit(baseline), (a, b, ids))],
        [(jax.jit(optimized), (a, b))],
        fix_action="coalesce_or_tile_gather",
        accept_actions=("coalesce_or_tile_gather", "tile_into_vmem"),
        source="quantized matmul epilogue: dst[ids_dst[j]*stride+i]=sum")


# -- QUICKSILVER: cross-layer lookup chain ----------------------------------------

def _make_quicksilver():
    n, tbl = 1 << 12, 1 << 10
    table = _f((tbl, 8))
    e = jnp.abs(_f((n,), seed=4))

    def _nuclear_data(table, idx):           # NuclearData.hh
        return table[idx]

    def _macro_xs(table, idx):               # MacroscopicCrossSection.hh
        row = _nuclear_data(table, idx)
        return row.sum(-1)

    def baseline(table, e):                  # CollisionEvent.hh
        idx = (e * tbl).astype(jnp.int32) % tbl
        return _macro_xs(table, idx) * e

    def optimized(table, e):
        # integer-hash + contiguous extract: kills the dependent gather
        sums = table.sum(-1)                      # one contiguous pass
        reps = -(-n // tbl)
        return jnp.tile(sums, reps)[:n] * e

    return Workload(
        "QUICKSILVER", [(jax.jit(baseline), (table, e))],
        [(jax.jit(optimized), (table, e))],
        fix_action="coalesce_or_tile_gather",
        accept_actions=("coalesce_or_tile_gather",),
        source="cross-section lookup through three call layers")


def build_suite() -> List[Workload]:
    return [
        _make_ltimes("LTIMES"),
        _make_ltimes("LTIMES_NOVIEW"),
        _make_gemm(),
        _make_mm("2MM", 2),
        _make_mm("3MM", 3),
        _make_fir(),
        _make_fusion("PRESSURE", 2),
        _make_fusion("ENERGY", 6),
        _make_indirect("VOL3D", 24),
        _make_indirect("ZONAL_ACCUM_3D", 8),
        _make_reduction(),
        _make_mass3dea(),
        _make_mulmatq(),
        _make_quicksilver(),
    ]
