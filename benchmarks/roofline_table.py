"""The 40-cell roofline baseline table (+ multi-pod) from dry-run artifacts.

Reads experiments/dryrun/*.json (produced by `repro.launch.dryrun`); emits
per-cell roofline terms, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio.
"""
from __future__ import annotations

import csv
import glob
import io
import json
import os
from typing import List

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_cells(mesh: str = "single") -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              f"*__{mesh}.json"))):
        r = json.load(open(path))
        arch, shape, _ = r["label"].split("__")
        if r.get("status") == "skipped":
            rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                         "status": "skipped", "dominant": "-",
                         "compute_ms": "", "memory_ms": "",
                         "collective_ms": "", "useful_ratio": "",
                         "roofline_fraction": "", "note": r["reason"][:60]})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                         "status": "error", "dominant": "-",
                         "compute_ms": "", "memory_ms": "",
                         "collective_ms": "", "useful_ratio": "",
                         "roofline_fraction": "",
                         "note": r.get("error", "")[:60]})
            continue
        rl = r["roofline"]
        rows.append({
            "arch": arch, "shape": shape, "mesh": mesh, "status": "ok",
            "dominant": rl["dominant"],
            "compute_ms": rl["compute_s"] * 1e3,
            "memory_ms": rl["memory_s"] * 1e3,
            "collective_ms": rl["collective_s"] * 1e3,
            "useful_ratio": rl["useful_ratio"],
            "roofline_fraction": rl["roofline_fraction"],
            "note": "",
        })
    return rows


def render_csv(rows) -> str:
    if not rows:
        return "no dry-run artifacts found; run repro.launch.dryrun first\n"
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
    w.writeheader()
    for r in rows:
        w.writerow({k: (f"{v:.3f}" if isinstance(v, float) else v)
                    for k, v in r.items()})
    return buf.getvalue()


def main():
    all_rows = []
    for mesh in ("single", "multi"):
        rows = load_cells(mesh)
        all_rows.extend(rows)
        if rows:
            ok = [r for r in rows if r["status"] == "ok"]
            print(f"# {mesh}-pod: {len(ok)} compiled, "
                  f"{sum(1 for r in rows if r['status'] == 'skipped')} "
                  f"skipped, "
                  f"{sum(1 for r in rows if r['status'] == 'error')} errors")
    print(render_csv(all_rows))
    return all_rows


if __name__ == "__main__":
    main()
