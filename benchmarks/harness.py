"""Shared measurement harness for the paper-table benchmarks.

A workload variant is a list of jitted stages (separate HloModules = separate
kernel launches).  For each stage we compile once, then hand the HLO text to
a shared :class:`LeoService` — the service's content-hash caches mean a
stage reused across variants/backends is parsed once and its per-backend
graphs are built once.  The variant's model time is the sum of stage
estimated times — so inter-kernel HBM traffic (stage outputs re-read by the
next stage) is naturally priced, and kernel fusion shows up as real speedup.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax

from repro.core import (
    Backend,
    BackendRegistry,
    Diagnosis,
    LeoAnalysis,
    LeoService,
    Recommendation,
    resolve_backend,
)


@dataclass
class VariantResult:
    seconds: float
    analyses: List[LeoAnalysis]
    recs: List[Recommendation]
    root_cause: str
    wall_us: float = 0.0
    diagnosis: Optional[Diagnosis] = None   # dominant stage, serializable


def _root_cause_label(an: LeoAnalysis) -> str:
    top = an.top_root_causes(1)
    if top:
        q, _ = top[0]
        instr = an.module.find(q)
        if instr is not None:
            scope = instr.op_name.rsplit("/", 2)[-1] if instr.op_name else ""
            return f"{instr.opcode}" + (f" @{scope}" if scope else "")
    diagnosed = list(an.blame.self_blame) + \
        list(getattr(an.blame, "occupancy_blame", []))
    if diagnosed:
        s = max(diagnosed, key=lambda s: s.cycles)
        return f"self:{s.subcategory}"
    return "none"


_HLO_CACHE: Dict[Tuple[int, int], str] = {}

#: One service for the whole benchmark process: every table/figure shares
#: the parse/graph/analysis caches (unbounded here — a benchmark run wants
#: to keep everything it touched).
SERVICE = LeoService(parse_cache_size=None, graph_cache_size=None,
                     analysis_cache_size=None, diagnosis_cache_size=None)

#: Backwards-compatible alias: the cached session under the service.
SESSION = SERVICE.session


def analyze_variant(stages, hw, time_wall: bool = False) -> VariantResult:
    """`hw` accepts a backend name, Backend, or bare HardwareModel."""
    backend = resolve_backend(hw)
    analyses: List[LeoAnalysis] = []
    total = 0.0
    wall_us = 0.0
    inter_bytes = 0.0
    for fn, args in stages:
        key = (id(fn), id(args))
        if key not in _HLO_CACHE:
            _HLO_CACHE[key] = jax.jit(fn).lower(*args).compile().as_text()
        an = SERVICE.analyze(_HLO_CACHE[key], backend=backend)
        module = an.module
        analyses.append(an)
        total += an.estimated_step_seconds
        root = module.entry_computation.root
        if root is not None:
            inter_bytes += root.shape.byte_size
        if time_wall:
            out = fn(*args)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(3):
                out = fn(*args)
            jax.block_until_ready(out)
            wall_us += (time.perf_counter() - t0) / 3 * 1e6

    # combined recommendations (primary = the dominant stage's)
    dominant = max(analyses, key=lambda a: a.estimated_step_seconds)
    diag = Diagnosis.from_analysis(dominant)
    if len(stages) > 1:
        # inter-kernel traffic diagnosis: stage boundaries force the full
        # intermediate field through HBM each launch
        diag.recommendations.insert(0, Recommendation(
            action="fuse_kernels", target="<pipeline>", scope="",
            reason=f"{len(stages)} kernel launches round-trip "
                   f"{inter_bytes/2**20:.1f} MiB of intermediates through "
                   "HBM; fuse into one kernel.",
            est_cycles=inter_bytes / backend.hw.hbm_bw * backend.hw.clock_hz))
    return VariantResult(seconds=total, analyses=analyses,
                         recs=list(diag.recommendations),
                         root_cause=_root_cause_label(dominant),
                         wall_us=wall_us, diagnosis=diag)


def geomean(values: List[float]) -> float:
    import math
    vals = [v for v in values if v > 0]
    if not vals:
        return 0.0
    return math.exp(sum(math.log(v) for v in vals) / len(vals))
