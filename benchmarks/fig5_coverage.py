"""Fig. 5 analogue: single-dependency coverage before/after LEO's workflow
(synchronization tracing + four-stage pruning), per workload x backend."""
from __future__ import annotations

import csv
import io
from typing import List

from repro.core import get_backend, list_backends

from .harness import analyze_variant
from .workloads import build_suite


def run(backends=None) -> List[dict]:
    """Defaults to every registered backend (3 TPU + NVIDIA/AMD/Intel-class),
    so the coverage table spans genuinely different vendors like the paper's
    21-cell figure."""
    names = list(backends) if backends is not None \
        else [b.name for b in list_backends()]
    rows: List[dict] = []
    suite = build_suite()
    for hw_name in names:
        backend = get_backend(hw_name)
        for w in suite:
            res = analyze_variant(w.baseline, backend)
            an = max(res.analyses, key=lambda a: a.estimated_step_seconds)
            rows.append({
                "workload": w.name, "backend": hw_name,
                "coverage_before": an.coverage_before.coverage,
                "coverage_after": an.coverage_after.coverage,
                "edges_initial": an.prune_stats.initial_edges,
                "edges_surviving": an.prune_stats.surviving_edges,
            })
    return rows


def render_csv(rows) -> str:
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
    w.writeheader()
    for r in rows:
        w.writerow({k: (f"{v:.3f}" if isinstance(v, float) else v)
                    for k, v in r.items()})
    return buf.getvalue()


def main():
    rows = run()
    print(render_csv(rows))
    above80 = sum(1 for r in rows if r["coverage_after"] >= 0.8)
    print(f"# {above80}/{len(rows)} workload-backend cells >= 80% after "
          "pruning (paper: 13/21 on GH200)")
    return rows


if __name__ == "__main__":
    main()
