"""`bench-smoke`: the CI perf-trajectory lane (PR-4 satellite).

A trimmed subset of the `benchmarks.run` suite: four Table-IV workload
baselines (one per family — strided contraction, matmul chain,
multi-stage, gather-heavy) plus the deterministic HLO fixture builders
(async demo, copy storms, wide ops), fanned across every registered
backend through one :class:`LeoService`.  The gated metric is the
**geomean modeled step time per backend** — the same
`estimated_step_seconds` the paper tables derive from — which is a pure
function of the analytical model, so a >10% drift can only mean the
model (sampler, issue model, sync scoreboard, backend constants)
changed.  Intentional recalibrations re-baseline with
``--update-baseline``; anything else is a perf regression CI should
block.

Wall-clock analysis time is also recorded (informational only — CI
runners are too noisy to gate on).

  PYTHONPATH=src python -m benchmarks.bench_smoke            # gate
  PYTHONPATH=src python -m benchmarks.bench_smoke --update-baseline
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from typing import Dict, List

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
DEFAULT_OUTPUT = "BENCH.json"
DEFAULT_THRESHOLD = 0.10


#: Table-IV workloads in the trimmed subset (one per family).
TABLE4_SUBSET = ("LTIMES", "GEMM", "PRESSURE", "MASS3DEA")


def workloads() -> Dict[str, str]:
    """Deterministic named HLO workloads (shared fixture builders)."""
    from repro.launch.analysis_server import (
        copy_storm_hlo,
        demo_hlo,
        wide_ops_hlo,
    )
    return {
        "demo_async_128": demo_hlo(seed=0, n=128, trips=5),
        "demo_async_192": demo_hlo(seed=1, n=192, trips=8),
        "copystorm_8": copy_storm_hlo(8),
        "copystorm_12": copy_storm_hlo(12),
        "wide_ops_12": wide_ops_hlo(),
    }


def table4_hlo() -> Dict[str, str]:
    """Compiled baseline HLO for the trimmed Table-IV workload subset
    (jax compiles each stage once; ~seconds)."""
    import jax

    from benchmarks.workloads import build_suite
    out: Dict[str, str] = {}
    for w in build_suite():
        if w.name not in TABLE4_SUBSET:
            continue
        for i, (fn, args) in enumerate(w.baseline):
            hlo = jax.jit(fn).lower(*args).compile().as_text()
            out[f"table4_{w.name}_s{i}"] = hlo
    return out


def run_bench() -> Dict[str, object]:
    from repro.core import LeoService

    service = LeoService()
    loads = dict(workloads())
    loads.update(table4_hlo())
    backends = sorted(b.name for b in service.session.backends)
    per_backend: Dict[str, Dict[str, float]] = {}
    t0 = time.perf_counter()
    for name, hlo in loads.items():
        diags = service.diagnose_fanout(hlo, hints={"total_devices": 8})
        for backend, diag in diags.items():
            per_backend.setdefault(backend, {})[name] = \
                diag.estimated_step_seconds
    wall = time.perf_counter() - t0

    geomeans = {
        backend: math.exp(sum(math.log(t) for t in times.values())
                          / len(times))
        for backend, times in per_backend.items()
    }
    return {
        "schema": 1,
        "metric": "geomean_estimated_step_seconds",
        "workloads": sorted(loads),
        "backends": backends,
        "geomean_estimated_step_seconds": {
            b: geomeans[b] for b in sorted(geomeans)},
        "per_workload_seconds": {
            b: dict(sorted(per_backend[b].items()))
            for b in sorted(per_backend)},
        "wall_seconds_informational": wall,
    }


def compare(result: Dict[str, object], baseline: Dict[str, object],
            threshold: float) -> List[str]:
    """Drift beyond the threshold in EITHER direction, as messages.

    The metric is a deterministic modeled quantity, so an unexplained
    speedup is model drift too — letting it pass would bank headroom
    that masks a later genuine slowdown.  Intentional changes
    re-baseline with ``--update-baseline``."""
    failures = []
    base = baseline.get("geomean_estimated_step_seconds", {})
    got = result["geomean_estimated_step_seconds"]
    for backend in sorted(base):
        if backend not in got:
            failures.append(f"{backend}: present in baseline but not in "
                            f"this run (backend vanished?)")
            continue
        ratio = got[backend] / base[backend]
        if ratio > 1.0 + threshold:
            failures.append(
                f"{backend}: geomean step time {got[backend]:.4e}s is "
                f"{(ratio - 1.0) * 100:.1f}% slower than baseline "
                f"{base[backend]:.4e}s (gate: {threshold * 100:.0f}%)")
        elif ratio < 1.0 - threshold:
            failures.append(
                f"{backend}: geomean step time {got[backend]:.4e}s is "
                f"{(1.0 - ratio) * 100:.1f}% FASTER than baseline "
                f"{base[backend]:.4e}s — unexplained model drift; if "
                f"intentional, re-baseline with --update-baseline")
    for backend in sorted(set(got) - set(base)):
        failures.append(
            f"{backend}: not in the committed baseline — its perf "
            f"trajectory is untracked; add it with --update-baseline")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", "--output", dest="output",
                    default=DEFAULT_OUTPUT,
                    help="result JSON path (uploaded as a CI artifact); "
                         "--output kept as an alias for older lanes")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline JSON to gate against")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="allowed slowdown fraction (default 0.10)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run "
                         "(intentional recalibration) instead of gating")
    args = ap.parse_args(argv)

    result = run_bench()
    with open(args.output, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.output} "
          f"({len(result['backends'])} backends x "
          f"{len(result['workloads'])} workloads in "
          f"{result['wall_seconds_informational']:.2f}s)")
    for backend, geo in result["geomean_estimated_step_seconds"].items():
        print(f"  {backend:<16s} geomean est. step {geo:.4e}s")

    if args.update_baseline:
        with open(args.baseline, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline updated: {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"ERROR: no baseline at {args.baseline}; commit one with "
              f"--update-baseline", file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(result, baseline, args.threshold)
    if failures:
        print("PERF REGRESSION vs committed baseline:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print(f"perf gate OK: no backend >"
          f"{args.threshold * 100:.0f}% slower than baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
