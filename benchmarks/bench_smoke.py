"""`bench-smoke`: the CI perf-trajectory lane (PR-4 satellite).

A trimmed subset of the `benchmarks.run` suite: four Table-IV workload
baselines (one per family — strided contraction, matmul chain,
multi-stage, gather-heavy) plus the deterministic HLO fixture builders
(async demo, copy storms, wide ops), fanned across every registered
backend through one :class:`LeoService`.  The gated metric is the
**geomean modeled step time per backend** — the same
`estimated_step_seconds` the paper tables derive from — which is a pure
function of the analytical model, so a >10% drift can only mean the
model (sampler, issue model, sync scoreboard, backend constants)
changed.  Intentional recalibrations re-baseline with
``--update-baseline``; anything else is a perf regression CI should
block.

Wall-clock analysis time is also recorded (informational only — CI
runners are too noisy to gate on).

The PR-7 advisor lane rides along: on the 48-copy storm, a fresh-cache
``diagnose(advise=True)`` (pipeline + what-if replays) must stay under
3x a fresh-cache plain ``diagnose`` per GPU backend.  Both sides are
best-of-N cold runs, so the ratio compares the same parse + pipeline
work and isolates the advisor's replay overhead — the one knob
``Advisor(max_candidates_per_rule=...)`` bounds.

The PR-8 rewrite lane extends it: a fresh-cache ``diagnose(rewrite=
True)`` — advisor + program rewrites + a full re-analysis of every
rewritten text — must stay under 4x the plain pipeline per GPU backend.

The PR-9 occupancy lane rides the same protocol: a fresh-cache
``diagnose(options=DiagnoseOptions(occupancy=True))`` — the pipeline
re-run under the part's native wave residency — must stay under 5x the
plain pipeline per GPU backend.

Each run also appends its geomeans to the committed
``benchmarks/trajectory.json`` (keyed by the output artifact name, so
re-running the same PR's lane replaces, never duplicates) — the
cross-PR perf trajectory in one diffable file.

  PYTHONPATH=src python -m benchmarks.bench_smoke            # gate
  PYTHONPATH=src python -m benchmarks.bench_smoke --update-baseline
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from typing import Dict, List

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
DEFAULT_TRAJECTORY = os.path.join(os.path.dirname(__file__),
                                  "trajectory.json")
DEFAULT_OUTPUT = "BENCH_pr9.json"
DEFAULT_THRESHOLD = 0.10

#: Advisor-lane gate: advise=True must cost < this multiple of the plain
#: pipeline on the same cold cache (ISSUE PR-7 satellite).
ADVISOR_GATE = 3.0
ADVISOR_BACKENDS = ("nvidia_gh200", "amd_mi300a", "intel_pvc")
ADVISOR_REPEATS = 3

#: Rewrite-lane gate: rewrite=True (advisor + rewrites + re-analysis of
#: every rewritten text) must cost < this multiple of the plain pipeline
#: on the same cold cache (ISSUE PR-8 satellite).
REWRITE_GATE = 4.0

#: Occupancy-lane gate: occupancy=True (the pipeline under the part's
#: native wave residency, cached separately by the derived backend name)
#: must cost < this multiple of the plain pipeline on the same cold
#: cache (ISSUE PR-9 satellite).
OCCUPANCY_GATE = 5.0


#: Table-IV workloads in the trimmed subset (one per family).
TABLE4_SUBSET = ("LTIMES", "GEMM", "PRESSURE", "MASS3DEA")


def workloads() -> Dict[str, str]:
    """Deterministic named HLO workloads (shared fixture builders)."""
    from repro.launch.analysis_server import (
        copy_storm_hlo,
        demo_hlo,
        wide_ops_hlo,
    )
    return {
        "demo_async_128": demo_hlo(seed=0, n=128, trips=5),
        "demo_async_192": demo_hlo(seed=1, n=192, trips=8),
        "copystorm_8": copy_storm_hlo(8),
        "copystorm_12": copy_storm_hlo(12),
        "wide_ops_12": wide_ops_hlo(),
    }


def table4_hlo() -> Dict[str, str]:
    """Compiled baseline HLO for the trimmed Table-IV workload subset
    (jax compiles each stage once; ~seconds)."""
    import jax

    from benchmarks.workloads import build_suite
    out: Dict[str, str] = {}
    for w in build_suite():
        if w.name not in TABLE4_SUBSET:
            continue
        for i, (fn, args) in enumerate(w.baseline):
            hlo = jax.jit(fn).lower(*args).compile().as_text()
            out[f"table4_{w.name}_s{i}"] = hlo
    return out


def run_bench() -> Dict[str, object]:
    from repro.core import LeoService

    service = LeoService()
    loads = dict(workloads())
    loads.update(table4_hlo())
    backends = sorted(b.name for b in service.session.backends)
    per_backend: Dict[str, Dict[str, float]] = {}
    t0 = time.perf_counter()
    for name, hlo in loads.items():
        diags = service.diagnose_fanout(hlo, hints={"total_devices": 8})
        for backend, diag in diags.items():
            per_backend.setdefault(backend, {})[name] = \
                diag.estimated_step_seconds
    wall = time.perf_counter() - t0

    geomeans = {
        backend: math.exp(sum(math.log(t) for t in times.values())
                          / len(times))
        for backend, times in per_backend.items()
    }
    return {
        "schema": 1,
        "metric": "geomean_estimated_step_seconds",
        "workloads": sorted(loads),
        "backends": backends,
        "geomean_estimated_step_seconds": {
            b: geomeans[b] for b in sorted(geomeans)},
        "per_workload_seconds": {
            b: dict(sorted(per_backend[b].items()))
            for b in sorted(per_backend)},
        "wall_seconds_informational": wall,
    }


def advisor_lane() -> Dict[str, object]:
    """Time plain vs advise=True diagnosis on the 48-copy storm.

    Every timing is a fresh :class:`LeoService` (cold memory/disk tiers),
    best-of-``ADVISOR_REPEATS`` — both sides pay the same parse +
    pipeline, so the ratio isolates the advisor's what-if replays."""
    from repro.core import DiagnoseOptions, LeoService
    from repro.launch.analysis_server import copy_storm_hlo

    hlo = copy_storm_hlo(48)

    def best_of(backend: str, advise: bool) -> float:
        opts = DiagnoseOptions(advise=advise)
        best = math.inf
        for _ in range(ADVISOR_REPEATS):
            service = LeoService()
            t0 = time.perf_counter()
            service.diagnose(hlo, backend=backend, options=opts)
            best = min(best, time.perf_counter() - t0)
        return best

    per_backend = {}
    for backend in ADVISOR_BACKENDS:
        pipeline_s = best_of(backend, advise=False)
        advise_s = best_of(backend, advise=True)
        per_backend[backend] = {
            "pipeline_seconds": pipeline_s,
            "advise_seconds": advise_s,
            "ratio": advise_s / pipeline_s,
        }
    return {
        "workload": "copystorm_48",
        "gate_ratio": ADVISOR_GATE,
        "repeats_best_of": ADVISOR_REPEATS,
        "per_backend": per_backend,
    }


def rewrite_lane() -> Dict[str, object]:
    """Time plain vs rewrite=True diagnosis on the 48-copy storm.

    Same cold best-of-N protocol as :func:`advisor_lane`; the ratio
    isolates advisor replays + rewrite application + the full
    re-analysis of every rewritten text (the most expensive part — each
    applied rewrite pays a second pipeline)."""
    from repro.core import DiagnoseOptions, LeoService
    from repro.launch.analysis_server import copy_storm_hlo

    hlo = copy_storm_hlo(48)

    def best_of(backend: str, rewrite: bool) -> float:
        opts = DiagnoseOptions(rewrite=rewrite)
        best = math.inf
        for _ in range(ADVISOR_REPEATS):
            service = LeoService()
            t0 = time.perf_counter()
            service.diagnose(hlo, backend=backend, options=opts)
            best = min(best, time.perf_counter() - t0)
        return best

    per_backend = {}
    for backend in ADVISOR_BACKENDS:
        pipeline_s = best_of(backend, rewrite=False)
        rewrite_s = best_of(backend, rewrite=True)
        per_backend[backend] = {
            "pipeline_seconds": pipeline_s,
            "rewrite_seconds": rewrite_s,
            "ratio": rewrite_s / pipeline_s,
        }
    return {
        "workload": "copystorm_48",
        "gate_ratio": REWRITE_GATE,
        "repeats_best_of": ADVISOR_REPEATS,
        "per_backend": per_backend,
    }


def occupancy_lane() -> Dict[str, object]:
    """Time plain vs occupancy=True diagnosis on the 48-copy storm.

    Same cold best-of-N protocol as :func:`advisor_lane`; the ratio
    isolates the residency-engaged pipeline re-run (the derived
    ``backend@wN`` name caches separately, so both sides pay one full
    parse + pipeline on their own key)."""
    from repro.core import DiagnoseOptions, LeoService
    from repro.launch.analysis_server import copy_storm_hlo

    hlo = copy_storm_hlo(48)

    def best_of(backend: str, occupancy: bool) -> float:
        opts = DiagnoseOptions(occupancy=occupancy)
        best = math.inf
        for _ in range(ADVISOR_REPEATS):
            service = LeoService()
            t0 = time.perf_counter()
            service.diagnose(hlo, backend=backend, options=opts)
            best = min(best, time.perf_counter() - t0)
        return best

    per_backend = {}
    for backend in ADVISOR_BACKENDS:
        pipeline_s = best_of(backend, occupancy=False)
        occupancy_s = best_of(backend, occupancy=True)
        per_backend[backend] = {
            "pipeline_seconds": pipeline_s,
            "occupancy_seconds": occupancy_s,
            "ratio": occupancy_s / pipeline_s,
        }
    return {
        "workload": "copystorm_48",
        "gate_ratio": OCCUPANCY_GATE,
        "repeats_best_of": ADVISOR_REPEATS,
        "per_backend": per_backend,
    }


def occupancy_failures(lane: Dict[str, object]) -> List[str]:
    failures = []
    for backend, row in sorted(lane["per_backend"].items()):
        if row["ratio"] >= lane["gate_ratio"]:
            failures.append(
                f"{backend}: occupancy=True diagnosis took "
                f"{row['occupancy_seconds']:.4f}s = {row['ratio']:.2f}x "
                f"the plain pipeline ({row['pipeline_seconds']:.4f}s); "
                f"the occupancy lane gates at < "
                f"{lane['gate_ratio']:.1f}x — did the wave credit "
                f"tracker grow per-event state?")
    return failures


def rewrite_failures(lane: Dict[str, object]) -> List[str]:
    failures = []
    for backend, row in sorted(lane["per_backend"].items()):
        if row["ratio"] >= lane["gate_ratio"]:
            failures.append(
                f"{backend}: rewrite=True diagnosis took "
                f"{row['rewrite_seconds']:.4f}s = {row['ratio']:.2f}x the "
                f"plain pipeline ({row['pipeline_seconds']:.4f}s); the "
                f"rewrite lane gates at < {lane['gate_ratio']:.1f}x — is "
                f"the loop re-analyzing more candidates than it applies?")
    return failures


def append_trajectory(result: Dict[str, object], output: str,
                      path: str = DEFAULT_TRAJECTORY) -> Dict[str, object]:
    """Append this run's geomeans to the committed trajectory file,
    keyed by the output artifact name (re-running one PR's lane replaces
    its own entry instead of growing the list)."""
    trajectory: Dict[str, object] = {"schema": 1, "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            trajectory = json.load(f)
    name = os.path.basename(output)
    runs = [r for r in trajectory.get("runs", []) if r.get("name") != name]
    runs.append({
        "name": name,
        "geomean_estimated_step_seconds":
            dict(result["geomean_estimated_step_seconds"]),
    })
    trajectory["runs"] = runs
    with open(path, "w") as f:
        json.dump(trajectory, f, indent=2, sort_keys=True)
        f.write("\n")
    return trajectory


def advisor_failures(lane: Dict[str, object]) -> List[str]:
    failures = []
    for backend, row in sorted(lane["per_backend"].items()):
        if row["ratio"] >= lane["gate_ratio"]:
            failures.append(
                f"{backend}: advise=True diagnosis took "
                f"{row['advise_seconds']:.4f}s = {row['ratio']:.2f}x the "
                f"plain pipeline ({row['pipeline_seconds']:.4f}s); the "
                f"advisor lane gates at < {lane['gate_ratio']:.1f}x — "
                f"did a rule start proposing unbounded candidates?")
    return failures


def compare(result: Dict[str, object], baseline: Dict[str, object],
            threshold: float) -> List[str]:
    """Drift beyond the threshold in EITHER direction, as messages.

    The metric is a deterministic modeled quantity, so an unexplained
    speedup is model drift too — letting it pass would bank headroom
    that masks a later genuine slowdown.  Intentional changes
    re-baseline with ``--update-baseline``."""
    failures = []
    base = baseline.get("geomean_estimated_step_seconds", {})
    got = result["geomean_estimated_step_seconds"]
    for backend in sorted(base):
        if backend not in got:
            failures.append(f"{backend}: present in baseline but not in "
                            f"this run (backend vanished?)")
            continue
        ratio = got[backend] / base[backend]
        if ratio > 1.0 + threshold:
            failures.append(
                f"{backend}: geomean step time {got[backend]:.4e}s is "
                f"{(ratio - 1.0) * 100:.1f}% slower than baseline "
                f"{base[backend]:.4e}s (gate: {threshold * 100:.0f}%)")
        elif ratio < 1.0 - threshold:
            failures.append(
                f"{backend}: geomean step time {got[backend]:.4e}s is "
                f"{(1.0 - ratio) * 100:.1f}% FASTER than baseline "
                f"{base[backend]:.4e}s — unexplained model drift; if "
                f"intentional, re-baseline with --update-baseline")
    for backend in sorted(set(got) - set(base)):
        failures.append(
            f"{backend}: not in the committed baseline — its perf "
            f"trajectory is untracked; add it with --update-baseline")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", "--output", dest="output",
                    default=DEFAULT_OUTPUT,
                    help="result JSON path (uploaded as a CI artifact); "
                         "--output kept as an alias for older lanes")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline JSON to gate against")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="allowed slowdown fraction (default 0.10)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run "
                         "(intentional recalibration) instead of gating")
    ap.add_argument("--trajectory", default=DEFAULT_TRAJECTORY,
                    help="committed cross-PR trajectory JSON to append "
                         "this run's geomeans to")
    args = ap.parse_args(argv)

    result = run_bench()
    result["advisor"] = advisor_lane()
    result["rewrite"] = rewrite_lane()
    result["occupancy"] = occupancy_lane()
    with open(args.output, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    append_trajectory(result, args.output, args.trajectory)
    print(f"wrote {args.output} "
          f"({len(result['backends'])} backends x "
          f"{len(result['workloads'])} workloads in "
          f"{result['wall_seconds_informational']:.2f}s); "
          f"trajectory appended to {args.trajectory}")
    for backend, geo in result["geomean_estimated_step_seconds"].items():
        print(f"  {backend:<16s} geomean est. step {geo:.4e}s")
    adv = result["advisor"]
    for backend, row in sorted(adv["per_backend"].items()):
        print(f"  {backend:<16s} advise=True {row['advise_seconds']:.4f}s "
              f"vs pipeline {row['pipeline_seconds']:.4f}s "
              f"({row['ratio']:.2f}x, gate <{adv['gate_ratio']:.0f}x)")
    rw = result["rewrite"]
    for backend, row in sorted(rw["per_backend"].items()):
        print(f"  {backend:<16s} rewrite=True {row['rewrite_seconds']:.4f}s "
              f"vs pipeline {row['pipeline_seconds']:.4f}s "
              f"({row['ratio']:.2f}x, gate <{rw['gate_ratio']:.0f}x)")
    occ = result["occupancy"]
    for backend, row in sorted(occ["per_backend"].items()):
        print(f"  {backend:<16s} occupancy=True "
              f"{row['occupancy_seconds']:.4f}s "
              f"vs pipeline {row['pipeline_seconds']:.4f}s "
              f"({row['ratio']:.2f}x, gate <{occ['gate_ratio']:.0f}x)")

    adv_failures = advisor_failures(adv)
    if adv_failures:
        print("ADVISOR OVERHEAD GATE failed:", file=sys.stderr)
        for msg in adv_failures:
            print(f"  {msg}", file=sys.stderr)
    rw_failures = rewrite_failures(rw)
    if rw_failures:
        print("REWRITE OVERHEAD GATE failed:", file=sys.stderr)
        for msg in rw_failures:
            print(f"  {msg}", file=sys.stderr)
    occ_failures = occupancy_failures(occ)
    if occ_failures:
        print("OCCUPANCY OVERHEAD GATE failed:", file=sys.stderr)
        for msg in occ_failures:
            print(f"  {msg}", file=sys.stderr)
    adv_failures = adv_failures + rw_failures + occ_failures

    if args.update_baseline:
        with open(args.baseline, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline updated: {args.baseline}")
        return 1 if adv_failures else 0

    if not os.path.exists(args.baseline):
        print(f"ERROR: no baseline at {args.baseline}; commit one with "
              f"--update-baseline", file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(result, baseline, args.threshold)
    if failures:
        print("PERF REGRESSION vs committed baseline:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
    if failures or adv_failures:
        return 1
    print(f"perf gate OK: no backend >"
          f"{args.threshold * 100:.0f}% slower than baseline; advisor "
          f"overhead < {adv['gate_ratio']:.0f}x, rewrite overhead "
          f"< {rw['gate_ratio']:.0f}x, and occupancy overhead "
          f"< {occ['gate_ratio']:.0f}x on all "
          f"{len(adv['per_backend'])} GPU backends")
    return 0


if __name__ == "__main__":
    sys.exit(main())
