"""`bench-smoke`: the CI perf-trajectory lane (PR-4 satellite).

A trimmed subset of the `benchmarks.run` suite: four Table-IV workload
baselines (one per family — strided contraction, matmul chain,
multi-stage, gather-heavy) plus the deterministic HLO fixture builders
(async demo, copy storms, wide ops), fanned across every registered
backend through one :class:`LeoService`.  The gated metric is the
**geomean modeled step time per backend** — the same
`estimated_step_seconds` the paper tables derive from — which is a pure
function of the analytical model, so a >10% drift can only mean the
model (sampler, issue model, sync scoreboard, backend constants)
changed.  Intentional recalibrations re-baseline with
``--update-baseline``; anything else is a perf regression CI should
block.

Wall-clock analysis time is also recorded (informational only — CI
runners are too noisy to gate on).

The PR-7 advisor lane rides along: on the 48-copy storm, a fresh-cache
``diagnose(advise=True)`` (pipeline + what-if replays) must stay under
3x a fresh-cache plain ``diagnose`` per GPU backend.  Both sides are
best-of-N cold runs, so the ratio compares the same parse + pipeline
work and isolates the advisor's replay overhead — the one knob
``Advisor(max_candidates_per_rule=...)`` bounds.

The PR-8 rewrite lane extends it: a fresh-cache ``diagnose(rewrite=
True)`` — advisor + program rewrites + a full re-analysis of every
rewritten text — must stay under 4x the plain pipeline per GPU backend.

The PR-9 occupancy lane rides the same protocol: a fresh-cache
``diagnose(options=DiagnoseOptions(occupancy=True))`` — the pipeline
re-run under the part's native wave residency — must stay under 5x the
plain pipeline per GPU backend.

The PR-10 serving lane measures multi-process throughput end-to-end: a
parse-heavy stream (every request a distinct trace, no shared cache
dir, so each pays a full HLO parse) is driven over the wire against
``analysis_server --serve 0 --workers 1`` and ``--workers 4``, and the
lane records RPS plus p50/p99 of the server-reported ``queue_seconds``
for both.  On machines with >= 4 CPUs (CI's runners) the 4-worker
server must sustain >= 2x the single-worker RPS — the pre-fork pool's
reason to exist is that parsing is GIL-bound in one process;
single-core machines record the measurement but skip the ratio gate.
Both servers must also drain to exit 0 on SIGTERM (gated everywhere).

Each run also appends its geomeans (and serving RPS) to the committed
``benchmarks/trajectory.json`` (keyed by the output artifact name, so
re-running the same PR's lane replaces, never duplicates) — the
cross-PR perf trajectory in one diffable file.

  PYTHONPATH=src python -m benchmarks.bench_smoke            # gate
  PYTHONPATH=src python -m benchmarks.bench_smoke --update-baseline
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from typing import Dict, List

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
DEFAULT_TRAJECTORY = os.path.join(os.path.dirname(__file__),
                                  "trajectory.json")
DEFAULT_OUTPUT = "BENCH_pr10.json"
DEFAULT_THRESHOLD = 0.10

#: Advisor-lane gate: advise=True must cost < this multiple of the plain
#: pipeline on the same cold cache (ISSUE PR-7 satellite).
ADVISOR_GATE = 3.0
ADVISOR_BACKENDS = ("nvidia_gh200", "amd_mi300a", "intel_pvc")
ADVISOR_REPEATS = 3

#: Rewrite-lane gate: rewrite=True (advisor + rewrites + re-analysis of
#: every rewritten text) must cost < this multiple of the plain pipeline
#: on the same cold cache (ISSUE PR-8 satellite).
REWRITE_GATE = 4.0

#: Occupancy-lane gate: occupancy=True (the pipeline under the part's
#: native wave residency, cached separately by the derived backend name)
#: must cost < this multiple of the plain pipeline on the same cold
#: cache (ISSUE PR-9 satellite).
OCCUPANCY_GATE = 5.0

#: Serving-lane gate: ``--workers 4`` must sustain >= this multiple of
#: the ``--workers 1`` RPS on the parse-heavy stream (ISSUE PR-10
#: tentpole).  Only enforced with >= SERVING_MIN_CPUS cores — on fewer
#: there is no parallelism for the pool to unlock, so the lane records
#: the measurement without gating the ratio.
SERVING_GATE = 2.0
SERVING_MIN_CPUS = 4
SERVING_WORKER_COUNTS = (1, 4)
SERVING_REQUESTS = 48
SERVING_CONCURRENCY = 8


#: Table-IV workloads in the trimmed subset (one per family).
TABLE4_SUBSET = ("LTIMES", "GEMM", "PRESSURE", "MASS3DEA")


def workloads() -> Dict[str, str]:
    """Deterministic named HLO workloads (shared fixture builders)."""
    from repro.launch.analysis_server import (
        copy_storm_hlo,
        demo_hlo,
        wide_ops_hlo,
    )
    return {
        "demo_async_128": demo_hlo(seed=0, n=128, trips=5),
        "demo_async_192": demo_hlo(seed=1, n=192, trips=8),
        "copystorm_8": copy_storm_hlo(8),
        "copystorm_12": copy_storm_hlo(12),
        "wide_ops_12": wide_ops_hlo(),
    }


def table4_hlo() -> Dict[str, str]:
    """Compiled baseline HLO for the trimmed Table-IV workload subset
    (jax compiles each stage once; ~seconds)."""
    import jax

    from benchmarks.workloads import build_suite
    out: Dict[str, str] = {}
    for w in build_suite():
        if w.name not in TABLE4_SUBSET:
            continue
        for i, (fn, args) in enumerate(w.baseline):
            hlo = jax.jit(fn).lower(*args).compile().as_text()
            out[f"table4_{w.name}_s{i}"] = hlo
    return out


def run_bench() -> Dict[str, object]:
    from repro.core import LeoService

    service = LeoService()
    loads = dict(workloads())
    loads.update(table4_hlo())
    backends = sorted(b.name for b in service.session.backends)
    per_backend: Dict[str, Dict[str, float]] = {}
    t0 = time.perf_counter()
    for name, hlo in loads.items():
        diags = service.diagnose_fanout(hlo, hints={"total_devices": 8})
        for backend, diag in diags.items():
            per_backend.setdefault(backend, {})[name] = \
                diag.estimated_step_seconds
    wall = time.perf_counter() - t0

    geomeans = {
        backend: math.exp(sum(math.log(t) for t in times.values())
                          / len(times))
        for backend, times in per_backend.items()
    }
    return {
        "schema": 1,
        "metric": "geomean_estimated_step_seconds",
        "workloads": sorted(loads),
        "backends": backends,
        "geomean_estimated_step_seconds": {
            b: geomeans[b] for b in sorted(geomeans)},
        "per_workload_seconds": {
            b: dict(sorted(per_backend[b].items()))
            for b in sorted(per_backend)},
        "wall_seconds_informational": wall,
    }


def advisor_lane() -> Dict[str, object]:
    """Time plain vs advise=True diagnosis on the 48-copy storm.

    Every timing is a fresh :class:`LeoService` (cold memory/disk tiers),
    best-of-``ADVISOR_REPEATS`` — both sides pay the same parse +
    pipeline, so the ratio isolates the advisor's what-if replays."""
    from repro.core import DiagnoseOptions, LeoService
    from repro.launch.analysis_server import copy_storm_hlo

    hlo = copy_storm_hlo(48)

    def best_of(backend: str, advise: bool) -> float:
        opts = DiagnoseOptions(advise=advise)
        best = math.inf
        for _ in range(ADVISOR_REPEATS):
            service = LeoService()
            t0 = time.perf_counter()
            service.diagnose(hlo, backend=backend, options=opts)
            best = min(best, time.perf_counter() - t0)
        return best

    per_backend = {}
    for backend in ADVISOR_BACKENDS:
        pipeline_s = best_of(backend, advise=False)
        advise_s = best_of(backend, advise=True)
        per_backend[backend] = {
            "pipeline_seconds": pipeline_s,
            "advise_seconds": advise_s,
            "ratio": advise_s / pipeline_s,
        }
    return {
        "workload": "copystorm_48",
        "gate_ratio": ADVISOR_GATE,
        "repeats_best_of": ADVISOR_REPEATS,
        "per_backend": per_backend,
    }


def rewrite_lane() -> Dict[str, object]:
    """Time plain vs rewrite=True diagnosis on the 48-copy storm.

    Same cold best-of-N protocol as :func:`advisor_lane`; the ratio
    isolates advisor replays + rewrite application + the full
    re-analysis of every rewritten text (the most expensive part — each
    applied rewrite pays a second pipeline)."""
    from repro.core import DiagnoseOptions, LeoService
    from repro.launch.analysis_server import copy_storm_hlo

    hlo = copy_storm_hlo(48)

    def best_of(backend: str, rewrite: bool) -> float:
        opts = DiagnoseOptions(rewrite=rewrite)
        best = math.inf
        for _ in range(ADVISOR_REPEATS):
            service = LeoService()
            t0 = time.perf_counter()
            service.diagnose(hlo, backend=backend, options=opts)
            best = min(best, time.perf_counter() - t0)
        return best

    per_backend = {}
    for backend in ADVISOR_BACKENDS:
        pipeline_s = best_of(backend, rewrite=False)
        rewrite_s = best_of(backend, rewrite=True)
        per_backend[backend] = {
            "pipeline_seconds": pipeline_s,
            "rewrite_seconds": rewrite_s,
            "ratio": rewrite_s / pipeline_s,
        }
    return {
        "workload": "copystorm_48",
        "gate_ratio": REWRITE_GATE,
        "repeats_best_of": ADVISOR_REPEATS,
        "per_backend": per_backend,
    }


def occupancy_lane() -> Dict[str, object]:
    """Time plain vs occupancy=True diagnosis on the 48-copy storm.

    Same cold best-of-N protocol as :func:`advisor_lane`; the ratio
    isolates the residency-engaged pipeline re-run (the derived
    ``backend@wN`` name caches separately, so both sides pay one full
    parse + pipeline on their own key)."""
    from repro.core import DiagnoseOptions, LeoService
    from repro.launch.analysis_server import copy_storm_hlo

    hlo = copy_storm_hlo(48)

    def best_of(backend: str, occupancy: bool) -> float:
        opts = DiagnoseOptions(occupancy=occupancy)
        best = math.inf
        for _ in range(ADVISOR_REPEATS):
            service = LeoService()
            t0 = time.perf_counter()
            service.diagnose(hlo, backend=backend, options=opts)
            best = min(best, time.perf_counter() - t0)
        return best

    per_backend = {}
    for backend in ADVISOR_BACKENDS:
        pipeline_s = best_of(backend, occupancy=False)
        occupancy_s = best_of(backend, occupancy=True)
        per_backend[backend] = {
            "pipeline_seconds": pipeline_s,
            "occupancy_seconds": occupancy_s,
            "ratio": occupancy_s / pipeline_s,
        }
    return {
        "workload": "copystorm_48",
        "gate_ratio": OCCUPANCY_GATE,
        "repeats_best_of": ADVISOR_REPEATS,
        "per_backend": per_backend,
    }


def _drive_serving(workers: int, traces: List[str]) -> Dict[str, object]:
    """Spawn ``analysis_server --serve 0 --workers N`` as a subprocess,
    drive the parse-heavy stream at ``SERVING_CONCURRENCY`` over the
    wire, then SIGTERM and record the drain exit code.

    ``traces[0]`` is an unmeasured warmup (opens the client's pooled
    connections and proves the listener is answering); the measured
    stream is ``traces[1:]`` — all distinct, so with no ``--cache-dir``
    every request pays a full HLO parse on whichever worker accepted
    it."""
    import shutil
    import signal
    import subprocess
    import tempfile
    from concurrent.futures import ThreadPoolExecutor

    from repro.core.service import AnalyzeRequest
    from repro.serve import LeoClient

    workdir = tempfile.mkdtemp(prefix="leo-bench-serve-")
    port_file = os.path.join(workdir, "port")
    src_dir = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.analysis_server",
         "--serve", "0", "--workers", str(workers), "--slots", "2",
         "--max-queue", "64", "--port-file", port_file],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    queue_seconds: List[float] = []
    try:
        deadline = time.time() + 120.0
        while not os.path.exists(port_file):
            if proc.poll() is not None:
                raise RuntimeError(
                    f"serving-lane server (--workers {workers}) exited "
                    f"rc={proc.returncode} before binding")
            if time.time() > deadline:
                raise RuntimeError("serving-lane server never wrote its "
                                   "port file")
            time.sleep(0.1)
        with open(port_file) as f:
            port = int(f.read().strip())
        reqs = [AnalyzeRequest(hlo_text=t, backend="tpu_v5e")
                for t in traces[1:]]
        with LeoClient(host="127.0.0.1", port=port, max_retries=8,
                       backoff_base_seconds=0.05) as client:
            if not client.wait_ready(60.0):
                raise RuntimeError("serving-lane server never became "
                                   "ready")
            client.diagnose(traces[0], backend="tpu_v5e")     # warmup
            t0 = time.perf_counter()
            with ThreadPoolExecutor(
                    max_workers=SERVING_CONCURRENCY) as pool:
                futs = [pool.submit(client.submit_wire, r) for r in reqs]
                for fut in futs:
                    resp = fut.result()
                    q = (getattr(resp, "timing", None)
                         or {}).get("queue_seconds")
                    if isinstance(q, (int, float)):
                        queue_seconds.append(float(q))
            wall = time.perf_counter() - t0
        proc.send_signal(signal.SIGTERM)
        drain_rc = proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
        shutil.rmtree(workdir, ignore_errors=True)

    queue_seconds.sort()

    def pct(p: float) -> float:
        if not queue_seconds:
            return float("nan")
        return queue_seconds[min(len(queue_seconds) - 1,
                                 int(p * len(queue_seconds)))]

    return {
        "workers": workers,
        "requests": len(reqs),
        "wall_seconds": wall,
        "rps": len(reqs) / wall,
        "queue_seconds_p50": pct(0.50),
        "queue_seconds_p99": pct(0.99),
        "drain_exit_code": drain_rc,
    }


def serving_lane() -> Dict[str, object]:
    """Multi-process serving throughput: the parse-heavy stream against
    ``--workers 1`` vs ``--workers 4`` (ISSUE PR-10).  Ratio-gated only
    on machines with >= ``SERVING_MIN_CPUS`` cores; clean SIGTERM drains
    are gated everywhere."""
    from repro.launch.analysis_server import demo_hlo

    cpu_count = os.cpu_count() or 1
    traces = [demo_hlo(seed=1000 + i, n=96 + 8 * (i % 6), trips=4)
              for i in range(SERVING_REQUESTS + 1)]
    per_workers = {str(w): _drive_serving(w, traces)
                   for w in SERVING_WORKER_COUNTS}
    lo = per_workers[str(min(SERVING_WORKER_COUNTS))]
    hi = per_workers[str(max(SERVING_WORKER_COUNTS))]
    return {
        "workload": f"{SERVING_REQUESTS} distinct demo_async traces "
                    f"(every request parses), concurrency "
                    f"{SERVING_CONCURRENCY}",
        "gate_rps_ratio": SERVING_GATE,
        "gated": cpu_count >= SERVING_MIN_CPUS,
        "cpu_count": cpu_count,
        "per_workers": per_workers,
        "rps_speedup": hi["rps"] / lo["rps"],
    }


def serving_failures(lane: Dict[str, object]) -> List[str]:
    failures = []
    for key, row in sorted(lane["per_workers"].items()):
        if row["drain_exit_code"] != 0:
            failures.append(
                f"--workers {key}: SIGTERM drain exited "
                f"{row['drain_exit_code']} (expected 0) — did a worker "
                f"miss the rolling drain deadline?")
    if lane["gated"] and lane["rps_speedup"] < lane["gate_rps_ratio"]:
        hi = str(max(SERVING_WORKER_COUNTS))
        lo = str(min(SERVING_WORKER_COUNTS))
        failures.append(
            f"--workers {hi} sustained only {lane['rps_speedup']:.2f}x "
            f"the --workers {lo} RPS "
            f"({lane['per_workers'][hi]['rps']:.1f} vs "
            f"{lane['per_workers'][lo]['rps']:.1f}) on "
            f"{lane['cpu_count']} CPUs; the serving lane gates at >= "
            f"{lane['gate_rps_ratio']:.1f}x — is the pool actually "
            f"forking, or are workers serializing on a shared lock?")
    return failures


def occupancy_failures(lane: Dict[str, object]) -> List[str]:
    failures = []
    for backend, row in sorted(lane["per_backend"].items()):
        if row["ratio"] >= lane["gate_ratio"]:
            failures.append(
                f"{backend}: occupancy=True diagnosis took "
                f"{row['occupancy_seconds']:.4f}s = {row['ratio']:.2f}x "
                f"the plain pipeline ({row['pipeline_seconds']:.4f}s); "
                f"the occupancy lane gates at < "
                f"{lane['gate_ratio']:.1f}x — did the wave credit "
                f"tracker grow per-event state?")
    return failures


def rewrite_failures(lane: Dict[str, object]) -> List[str]:
    failures = []
    for backend, row in sorted(lane["per_backend"].items()):
        if row["ratio"] >= lane["gate_ratio"]:
            failures.append(
                f"{backend}: rewrite=True diagnosis took "
                f"{row['rewrite_seconds']:.4f}s = {row['ratio']:.2f}x the "
                f"plain pipeline ({row['pipeline_seconds']:.4f}s); the "
                f"rewrite lane gates at < {lane['gate_ratio']:.1f}x — is "
                f"the loop re-analyzing more candidates than it applies?")
    return failures


def append_trajectory(result: Dict[str, object], output: str,
                      path: str = DEFAULT_TRAJECTORY) -> Dict[str, object]:
    """Append this run's geomeans to the committed trajectory file,
    keyed by the output artifact name (re-running one PR's lane replaces
    its own entry instead of growing the list)."""
    trajectory: Dict[str, object] = {"schema": 1, "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            trajectory = json.load(f)
    name = os.path.basename(output)
    runs = [r for r in trajectory.get("runs", []) if r.get("name") != name]
    entry = {
        "name": name,
        "geomean_estimated_step_seconds":
            dict(result["geomean_estimated_step_seconds"]),
    }
    serving = result.get("serving")
    if serving:
        entry["serving_rps"] = {
            w: row["rps"] for w, row in serving["per_workers"].items()}
        entry["serving_rps_speedup"] = serving["rps_speedup"]
    runs.append(entry)
    trajectory["runs"] = runs
    with open(path, "w") as f:
        json.dump(trajectory, f, indent=2, sort_keys=True)
        f.write("\n")
    return trajectory


def advisor_failures(lane: Dict[str, object]) -> List[str]:
    failures = []
    for backend, row in sorted(lane["per_backend"].items()):
        if row["ratio"] >= lane["gate_ratio"]:
            failures.append(
                f"{backend}: advise=True diagnosis took "
                f"{row['advise_seconds']:.4f}s = {row['ratio']:.2f}x the "
                f"plain pipeline ({row['pipeline_seconds']:.4f}s); the "
                f"advisor lane gates at < {lane['gate_ratio']:.1f}x — "
                f"did a rule start proposing unbounded candidates?")
    return failures


def compare(result: Dict[str, object], baseline: Dict[str, object],
            threshold: float) -> List[str]:
    """Drift beyond the threshold in EITHER direction, as messages.

    The metric is a deterministic modeled quantity, so an unexplained
    speedup is model drift too — letting it pass would bank headroom
    that masks a later genuine slowdown.  Intentional changes
    re-baseline with ``--update-baseline``."""
    failures = []
    base = baseline.get("geomean_estimated_step_seconds", {})
    got = result["geomean_estimated_step_seconds"]
    for backend in sorted(base):
        if backend not in got:
            failures.append(f"{backend}: present in baseline but not in "
                            f"this run (backend vanished?)")
            continue
        ratio = got[backend] / base[backend]
        if ratio > 1.0 + threshold:
            failures.append(
                f"{backend}: geomean step time {got[backend]:.4e}s is "
                f"{(ratio - 1.0) * 100:.1f}% slower than baseline "
                f"{base[backend]:.4e}s (gate: {threshold * 100:.0f}%)")
        elif ratio < 1.0 - threshold:
            failures.append(
                f"{backend}: geomean step time {got[backend]:.4e}s is "
                f"{(1.0 - ratio) * 100:.1f}% FASTER than baseline "
                f"{base[backend]:.4e}s — unexplained model drift; if "
                f"intentional, re-baseline with --update-baseline")
    for backend in sorted(set(got) - set(base)):
        failures.append(
            f"{backend}: not in the committed baseline — its perf "
            f"trajectory is untracked; add it with --update-baseline")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", "--output", dest="output",
                    default=DEFAULT_OUTPUT,
                    help="result JSON path (uploaded as a CI artifact); "
                         "--output kept as an alias for older lanes")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline JSON to gate against")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="allowed slowdown fraction (default 0.10)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run "
                         "(intentional recalibration) instead of gating")
    ap.add_argument("--trajectory", default=DEFAULT_TRAJECTORY,
                    help="committed cross-PR trajectory JSON to append "
                         "this run's geomeans to")
    args = ap.parse_args(argv)

    result = run_bench()
    result["advisor"] = advisor_lane()
    result["rewrite"] = rewrite_lane()
    result["occupancy"] = occupancy_lane()
    result["serving"] = serving_lane()
    with open(args.output, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    append_trajectory(result, args.output, args.trajectory)
    print(f"wrote {args.output} "
          f"({len(result['backends'])} backends x "
          f"{len(result['workloads'])} workloads in "
          f"{result['wall_seconds_informational']:.2f}s); "
          f"trajectory appended to {args.trajectory}")
    for backend, geo in result["geomean_estimated_step_seconds"].items():
        print(f"  {backend:<16s} geomean est. step {geo:.4e}s")
    adv = result["advisor"]
    for backend, row in sorted(adv["per_backend"].items()):
        print(f"  {backend:<16s} advise=True {row['advise_seconds']:.4f}s "
              f"vs pipeline {row['pipeline_seconds']:.4f}s "
              f"({row['ratio']:.2f}x, gate <{adv['gate_ratio']:.0f}x)")
    rw = result["rewrite"]
    for backend, row in sorted(rw["per_backend"].items()):
        print(f"  {backend:<16s} rewrite=True {row['rewrite_seconds']:.4f}s "
              f"vs pipeline {row['pipeline_seconds']:.4f}s "
              f"({row['ratio']:.2f}x, gate <{rw['gate_ratio']:.0f}x)")
    occ = result["occupancy"]
    for backend, row in sorted(occ["per_backend"].items()):
        print(f"  {backend:<16s} occupancy=True "
              f"{row['occupancy_seconds']:.4f}s "
              f"vs pipeline {row['pipeline_seconds']:.4f}s "
              f"({row['ratio']:.2f}x, gate <{occ['gate_ratio']:.0f}x)")
    srv = result["serving"]
    for key, row in sorted(srv["per_workers"].items()):
        print(f"  serving --workers {key}: {row['rps']:.1f} req/s, "
              f"queue p50 {row['queue_seconds_p50'] * 1e3:.1f}ms "
              f"p99 {row['queue_seconds_p99'] * 1e3:.1f}ms, "
              f"drain rc={row['drain_exit_code']}")
    gate_note = ("gate >= {:.1f}x".format(srv["gate_rps_ratio"])
                 if srv["gated"] else
                 "ratio informational on {} CPU(s)".format(
                     srv["cpu_count"]))
    print(f"  serving speedup {srv['rps_speedup']:.2f}x ({gate_note})")

    adv_failures = advisor_failures(adv)
    if adv_failures:
        print("ADVISOR OVERHEAD GATE failed:", file=sys.stderr)
        for msg in adv_failures:
            print(f"  {msg}", file=sys.stderr)
    rw_failures = rewrite_failures(rw)
    if rw_failures:
        print("REWRITE OVERHEAD GATE failed:", file=sys.stderr)
        for msg in rw_failures:
            print(f"  {msg}", file=sys.stderr)
    occ_failures = occupancy_failures(occ)
    if occ_failures:
        print("OCCUPANCY OVERHEAD GATE failed:", file=sys.stderr)
        for msg in occ_failures:
            print(f"  {msg}", file=sys.stderr)
    srv_failures = serving_failures(srv)
    if srv_failures:
        print("SERVING THROUGHPUT GATE failed:", file=sys.stderr)
        for msg in srv_failures:
            print(f"  {msg}", file=sys.stderr)
    adv_failures = adv_failures + rw_failures + occ_failures + srv_failures

    if args.update_baseline:
        with open(args.baseline, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline updated: {args.baseline}")
        return 1 if adv_failures else 0

    if not os.path.exists(args.baseline):
        print(f"ERROR: no baseline at {args.baseline}; commit one with "
              f"--update-baseline", file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(result, baseline, args.threshold)
    if failures:
        print("PERF REGRESSION vs committed baseline:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
    if failures or adv_failures:
        return 1
    srv_gate = (f"serving speedup >= {srv['gate_rps_ratio']:.1f}x"
                if srv["gated"] else "serving drains clean "
                "(ratio ungated on this core count)")
    print(f"perf gate OK: no backend >"
          f"{args.threshold * 100:.0f}% slower than baseline; advisor "
          f"overhead < {adv['gate_ratio']:.0f}x, rewrite overhead "
          f"< {rw['gate_ratio']:.0f}x, and occupancy overhead "
          f"< {occ['gate_ratio']:.0f}x on all "
          f"{len(adv['per_backend'])} GPU backends; {srv_gate}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
