"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary lines per benchmark, then
each table's full CSV.  Tables:

  table4   — root causes + LEO-guided optimization speedups x 3 backends
             (paper Table IV; derived = geomean speedup on v5e)
  table5   — diagnostic-context comparison C / C+S / C+L(S)
             (paper Table V; derived = C+L(S) action-match rate)
  fig5     — single-dependency coverage before/after pruning
             (paper Fig. 5; derived = mean coverage gain)
  roofline — the 40-cell (arch x shape) baseline + multi-pod table
             (§Roofline; derived = compiled-cell count)
"""
from __future__ import annotations

import time


def main() -> None:
    from . import fig5_coverage, roofline_table, table4_optimizations, \
        table5_llm_context

    summaries = []

    t0 = time.perf_counter()
    t4 = table4_optimizations.run()
    dt4 = (time.perf_counter() - t0) * 1e6
    geo = [r["speedup"] for r in t4
           if r["workload"] == "GEOMEAN" and r["backend"] == "tpu_v5e"][0]
    summaries.append(("table4_optimizations", dt4 / max(len(t4), 1),
                      f"geomean_speedup_v5e={geo:.3f}"))

    t0 = time.perf_counter()
    t5 = table5_llm_context.run()
    dt5 = (time.perf_counter() - t0) * 1e6
    cls_rate = t5["summary"]["C+L(S)"]["action_match_rate"]
    summaries.append(("table5_llm_context", dt5 / max(len(t5["rows"]), 1),
                      f"cls_match_rate={cls_rate:.2f}"))

    t0 = time.perf_counter()
    f5 = fig5_coverage.run()
    dt5b = (time.perf_counter() - t0) * 1e6
    gain = sum(r["coverage_after"] - r["coverage_before"] for r in f5) / \
        max(len(f5), 1)
    summaries.append(("fig5_coverage", dt5b / max(len(f5), 1),
                      f"mean_coverage_gain={gain:.3f}"))

    t0 = time.perf_counter()
    rl = roofline_table.load_cells("single") + \
        roofline_table.load_cells("multi")
    dtr = (time.perf_counter() - t0) * 1e6
    ok = sum(1 for r in rl if r["status"] == "ok")
    summaries.append(("roofline_table", dtr / max(len(rl), 1),
                      f"compiled_cells={ok}/{len(rl)}"))

    print("name,us_per_call,derived")
    for name, us, derived in summaries:
        print(f"{name},{us:.1f},{derived}")
    print()
    print("=== Table IV analogue (root causes & LEO-guided speedups) ===")
    print(table4_optimizations.render_csv(t4))
    print("=== Table V analogue (diagnostic context comparison) ===")
    print(table5_llm_context.render_csv(t5))
    print("=== Fig. 5 analogue (single-dependency coverage) ===")
    print(fig5_coverage.render_csv(f5))
    print("=== Roofline cells (dry-run artifacts) ===")
    print(roofline_table.render_csv(rl))


if __name__ == "__main__":
    main()
