"""Table IV analogue: root causes + LEO-guided optimization speedups across
three hardware backends (tpu_v5e / v5p / v4 play NVIDIA/AMD/Intel's role).

Speedups are model-time ratios from the shared analytical backend model
(baseline stages vs optimized stages), with the optimization confined to the
region implicated by LEO's top chain — the paper's restrictive protocol.
"""
from __future__ import annotations

import csv
import io
from typing import Dict, List

from repro.core import get_backend, list_backends

from .harness import analyze_variant, geomean
from .workloads import build_suite


def run(backends=None) -> List[dict]:
    """Defaults to every registered backend — the TPU trio the seed shipped
    plus the NVIDIA/AMD/Intel-class descriptors, matching the paper's
    three-vendor Table IV protocol."""
    names = list(backends) if backends is not None \
        else [b.name for b in list_backends()]
    rows: List[dict] = []
    suite = build_suite()
    for hw_name in names:
        hw = get_backend(hw_name)
        speedups = []
        for w in suite:
            base = analyze_variant(w.baseline, hw)
            opt = analyze_variant(w.optimized, hw)
            speedup = base.seconds / max(opt.seconds, 1e-12)
            speedups.append(speedup)
            rows.append({
                "workload": w.name,
                "backend": hw_name,
                "root_cause": base.root_cause,
                "leo_action": base.recs[0].action if base.recs else "none",
                "base_ms": base.seconds * 1e3,
                "opt_ms": opt.seconds * 1e3,
                "speedup": speedup,
            })
        rows.append({
            "workload": "GEOMEAN", "backend": hw_name, "root_cause": "",
            "leo_action": "", "base_ms": 0.0, "opt_ms": 0.0,
            "speedup": geomean(speedups),
        })
    return rows


def render_csv(rows: List[dict]) -> str:
    buf = io.StringIO()
    writer = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
    writer.writeheader()
    for r in rows:
        writer.writerow({k: (f"{v:.3f}" if isinstance(v, float) else v)
                         for k, v in r.items()})
    return buf.getvalue()


def main() -> List[dict]:
    rows = run()
    print(render_csv(rows))
    return rows


if __name__ == "__main__":
    main()
