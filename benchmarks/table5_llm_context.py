"""Table V analogue: diagnostic-context comparison (C vs C+S vs C+L(S)).

No network access exists here, so the "LLM" is a deterministic rule-based
optimizer — a *strategist* that must pick one transformation per workload
from the same action catalog LEO's recommendations use.  What varies is the
context each strategist sees, exactly mirroring §IV-B:

  C      — source code only: the strategist can only apply its generic
           default (optimize the math), like an LLM pattern-matching code;
  C+S    — code + raw top-stall site: picks the action suggested by the
           *symptom's* opcode at the stalled location — right when symptom
           and cause coincide, wrong when the cause is elsewhere
           (inter-kernel traffic, loop-carried serialization);
  C+L(S) — code + LEO's ranked recommendations: takes the top action.

A pick "succeeds" when it lands in the workload's accepted-fix set; the
achieved speedup is the Table-IV optimized variant's when it succeeds, 1.0x
otherwise.  This isolates exactly the paper's claim: causal chains beat raw
stall counts as optimization guidance.
"""
from __future__ import annotations

import csv
import io
from typing import Dict, List

from repro.core import OpClass, get_backend

from .harness import analyze_variant, geomean
from .workloads import build_suite


def _strategist_c(workload) -> str:
    return "increase_matmul_intensity"  # generic "make the math faster"


def _strategist_cs(workload, base_result) -> str:
    """Symptom-local pick from the top-stalled/top-occupancy site."""
    dominant = max(base_result.analyses,
                   key=lambda a: a.estimated_step_seconds)
    top = dominant.profile.top_stalled(1)
    qualified = None
    if top:
        qualified = top[0].qualified
    else:
        recs = sorted(dominant.profile.records.values(),
                      key=lambda r: -r.total_samples)
        for r in recs:
            instr = dominant.module.find(r.qualified)
            if instr is not None and instr.op_class not in (
                    OpClass.CONTROL, OpClass.PARAMETER, OpClass.TUPLE,
                    OpClass.CONSTANT):
                qualified = r.qualified
                break
    if qualified is None:
        return "increase_matmul_intensity"
    module = dominant.module
    instr = module.find(qualified)
    cls = instr.op_class
    opcodes = {instr.opcode}
    for cname in instr.called_computations:   # peek inside the hot fusion
        callee = module.computations.get(cname)
        if callee is not None:
            opcodes |= {i.opcode for i in callee.instructions}
    if cls is OpClass.MATMUL or "dot" in opcodes:
        return "increase_matmul_intensity"
    if opcodes & {"gather", "scatter", "dynamic-slice"}:
        return "coalesce_or_tile_gather"
    if cls in (OpClass.MEMORY_LOAD, OpClass.MEMORY_STORE):
        return "prefetch_or_double_buffer"
    if cls is OpClass.COLLECTIVE:
        return "overlap_or_reshard_collective"
    hw = dominant.hw
    if hw.memory_seconds(instr) > hw.compute_seconds(instr):
        # symptom says "loads are slow HERE" — without the causal chain the
        # local prescription is a prefetch, even when the real fix is
        # fusing kernels or restructuring a loop
        return "prefetch_or_double_buffer"
    return "already_compute_bound"


def _strategist_cls(workload, base_result) -> str:
    """C+L(S) pick from the *serialized* diagnosis — the strategist sees
    only the JSON payload an agent would receive over the wire, proving
    the guidance survives the Diagnosis schema round-trip."""
    from repro.core import Diagnosis
    if base_result.diagnosis is None:
        return "none"
    diag = Diagnosis.from_json(base_result.diagnosis.to_json())
    return diag.recommendations[0].action if diag.recommendations else "none"


def run(hw_name: str = "tpu_v5e") -> Dict[str, dict]:
    hw = get_backend(hw_name)
    suite = build_suite()
    per_level: Dict[str, dict] = {}
    rows = []
    for level in ("C", "C+S", "C+L(S)"):
        hits = 0
        speedups: List[float] = []
        for w in suite:
            base = analyze_variant(w.baseline, hw)
            opt = analyze_variant(w.optimized, hw)
            true_speedup = base.seconds / max(opt.seconds, 1e-12)
            if level == "C":
                action = _strategist_c(w)
            elif level == "C+S":
                action = _strategist_cs(w, base)
            else:
                action = _strategist_cls(w, base)
            accepted = w.accept_actions or (w.fix_action,)
            hit = action in accepted
            hits += hit
            speedups.append(true_speedup if hit else 1.0)
            rows.append({"level": level, "workload": w.name,
                         "action": action, "hit": hit,
                         "achieved": speedups[-1]})
        per_level[level] = {
            "action_match_rate": hits / len(suite),
            "geomean_speedup": geomean(speedups),
        }
    return {"summary": per_level, "rows": rows}


def render_csv(result) -> str:
    buf = io.StringIO()
    w = csv.writer(buf)
    w.writerow(["level", "action_match_rate", "geomean_speedup"])
    for level, stats in result["summary"].items():
        w.writerow([level, f"{stats['action_match_rate']:.2f}",
                    f"{stats['geomean_speedup']:.3f}"])
    w.writerow([])
    w.writerow(["level", "workload", "action", "hit", "achieved"])
    for r in result["rows"]:
        w.writerow([r["level"], r["workload"], r["action"],
                    int(r["hit"]), f"{r['achieved']:.2f}"])
    return buf.getvalue()


def main():
    result = run()
    print(render_csv(result))
    return result


if __name__ == "__main__":
    main()
